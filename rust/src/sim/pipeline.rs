//! The streaming pipeline executor: bounded queues, continuous-batching
//! instances, cross-node transfers, OOM restarts, and backpressure — the
//! substrate everything else schedules against.
//!
//! The paper runs Ray Data on an 8-node NPU cluster; this is the simulated
//! equivalent (DESIGN.md §Hardware-Adaptation).  Dynamics modelled:
//!
//! * **bounded buffers + blocking producers** — backpressure propagates
//!   upstream; the source is throttled exactly like Ray Data's streaming
//!   executor (offline paradigm: source rate is whatever downstream admits);
//! * **continuous batching** — accelerator instances form batches up to the
//!   config-dependent effective batch; busy-time covers any in-flight work,
//!   so useful-time estimators confound occupancy with capacity;
//! * **OOM restarts** — ground-truth peak memory above device capacity
//!   kills the instance for `cold_s`, with a short conservative-batch
//!   recovery phase (vLLM-style preemption after recovery);
//! * **network egress links** — one FIFO link per node; cross-node record
//!   transfers serialize behind it, so placement decisions matter;
//! * **DAG topology** — routing is indexed by pipeline *edge*, not by
//!   operator position.  A fork (several out-edges) replicates each output
//!   record onto every edge; a join (several in-edges) buffers partial
//!   results per item id and enqueues one merged record once every branch
//!   has delivered.  Join state is bounded (new groups need queue space,
//!   so backpressure reaches the branches) and its bytes are tracked
//!   against the hosting node ([`PipelineSim::join_state_mb`]).  Partials
//!   of a group already buffered are always admitted — completing a group
//!   frees space — which is what makes fork/join loops deadlock-free.
//!   A linear chain is the path-shaped special case and reproduces the
//!   pre-DAG executor event-for-event.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;

use crate::config::{ClusterSpec, OperatorKind, PipelineSpec, TenancyView};
use crate::rngx::Rng;
use crate::sim::engine::{Engine, Ev, InstId};
use crate::sim::items::{Item, ItemAttrs};
use crate::sim::metrics::{InstWindow, InstanceMetrics, OpMetrics, OpWindowAcc};
use crate::sim::net::{LinkEntry, TransferNet};
use crate::sim::service;
use crate::workload::Trace;

/// Typed instance-launch failures (the executor's admission errors used
/// to be stringly `Result<_, String>`; the rendered messages are
/// unchanged, so CLI strict-mode output and exit codes are too).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// The target node is marked down by the dynamics layer.
    NodeDown { node: usize },
    /// The target node has no free accelerator slots for the operator.
    OutOfAccelerators { node: usize, op: String, booked: u32, want: u32, cap: u32 },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::NodeDown { node } => write!(f, "node {node} is down"),
            SimError::OutOfAccelerators { node, op, booked, want, cap } => write!(
                f,
                "node {node} out of accelerators for {op} ({booked}+{want} > {cap})"
            ),
        }
    }
}

impl std::error::Error for SimError {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstState {
    Starting,
    Running,
    /// Down for an OOM/config restart.
    Restarting,
    /// Finishing in-flight work before stopping.
    Draining,
    Stopped,
}

pub struct Instance {
    pub op: usize,
    pub node: usize,
    pub theta: Vec<f64>,
    pub state: InstState,
    pub queue: VecDeque<Item>,
    /// Outputs finished but not yet admitted downstream (blocked sender),
    /// tagged with the pipeline edge they travel on.
    pub pending_out: VecDeque<(usize, Item)>,
    /// Join state: partial results per item id, one slot per in-edge
    /// (in-edge-list order).  Empty for non-join operators.
    pub join_buf: BTreeMap<u64, Vec<Option<Item>>>,
    /// Items of the in-flight batch (empty = idle).
    pub batch: Vec<Item>,
    batch_service_s: f64,
    /// Inbound transfers reserved against our queue capacity.
    pub reserved: usize,
    /// Fanout fractional carry.
    carry: f64,
    /// Remaining batches at halved size after an OOM recovery.
    conservative: u8,
    /// Bumped on every config restart (lets tuners attribute metrics).
    pub config_gen: u32,
    /// Pending config to apply at the next idle point.
    reconfig: Option<Vec<f64>>,
    /// True while a `try_place_outputs` frame for this instance is on the
    /// stack (its pending_out is temporarily taken, so the instance looks
    /// spuriously idle).  Join-completion cascades re-enter via
    /// `wake_waiters`; the guard makes them defer instead.
    placing: bool,
    // -- window accounting --
    pub win: InstWindow,
    win_start: f64,
    down_since: Option<f64>,
    pub created_at: f64,
}

impl Instance {
    fn occupancy(&self) -> usize {
        self.queue.len()
            + self.reserved
            + self.batch.len()
            + self.pending_out.len()
            + self.join_buf.len()
    }

    fn has_space(&self, cap: usize) -> bool {
        self.state != InstState::Stopped
            && self.state != InstState::Draining
            && self.queue.len() + self.reserved + self.join_buf.len() < cap
    }

    fn idle(&self) -> bool {
        self.batch.is_empty() && self.pending_out.is_empty()
    }
}

/// Per-node mutable state.  CPU bookings and the egress-link horizon are
/// kept *per tenant*: tenant `t`'s events only ever touch index `t`, which
/// is what lets the sharded facade advance disjoint tenant sets on worker
/// threads without cross-tenant reads.  Cross-tenant CPU contention is
/// applied through the window-frozen [`PipelineSim`] snapshot instead
/// (see `frozen_cpu`); the egress link is split into fixed WFQ sub-links
/// (see `egress_share`).
struct NodeState {
    /// CPU cores booked on this node, per tenant.
    cpu_booked: Vec<f64>,
    mem_booked: f64,
    accel_booked: u32,
    /// Egress sub-link busy-until timestamp, per tenant.
    link_free: Vec<f64>,
    egress_mb_window: f64,
    /// Bytes of buffered join partials hosted on this node (the DAG
    /// join-state memory, charged where the group is buffered).
    join_mb: f64,
}

/// Waiter sentinel for tenant 0's source; tenant t's sentinel is
/// `SOURCE - t` (instance ids never reach that range).
const SOURCE: usize = usize::MAX;

fn source_waiter(tenant: usize) -> usize {
    SOURCE - tenant
}

/// Lineage ids are namespaced per tenant so id minting never reads or
/// writes cross-tenant state (a sharding requirement): tenant 0 uses the
/// plain counter — single-tenant runs keep the legacy ids bit-for-bit —
/// and tenant t > 0 tags the top 16 bits.  48 counter bits is ~2.8e14
/// lineages per tenant, unreachable in simulation.
fn encode_item_id(tenant: usize, ctr: u64) -> u64 {
    debug_assert!(ctr < 1 << 48, "per-tenant lineage counter overflows 48 bits");
    if tenant == 0 {
        ctr
    } else {
        ((tenant as u64) << 48) | ctr
    }
}

/// The discrete-event pipeline simulator.  Hosts the disjoint per-tenant
/// DAGs of a [`TenancyView`] on shared nodes: memory and accelerator
/// slots are contended across tenants at admission, CPU contention is
/// applied through a window-frozen per-node snapshot, and each node's
/// egress link is split into fixed per-tenant WFQ sub-links — while
/// records never cross tenant DAGs (edge lists are disjoint).  Within a
/// window no event handler reads another tenant's mutable state, which is
/// what makes the tenant-sharded facade ([`ShardedSim`](crate::sim::ShardedSim))
/// bit-identical to this serial executor.  A single-tenant view
/// reproduces the classic one-pipeline executor event-for-event.
pub struct PipelineSim {
    pub engine: Engine,
    /// In-flight cross-node transfers: payload slab + per-node link FIFOs
    /// (batched mode stores entries here instead of the event heap; both
    /// stores are consumed in global `(time, seq)` order by `run_until`).
    net: TransferNet,
    /// Route transfers through one heap event per record (the legacy
    /// "seed event stream") instead of the batched link FIFOs.  Same
    /// `(time, seq)` delivery schedule either way — this is the measured
    /// baseline mode for `bench-perf` and the reference stream for the
    /// parity tests.
    seed_event_stream: bool,
    pub spec: PipelineSpec,
    pub cluster: ClusterSpec,
    /// Tenant structure of `spec` (trivial for [`PipelineSim::new`]).
    pub tenancy: TenancyView,
    /// One RNG stream per tenant: stream 0 is the legacy `Rng::new(seed)`
    /// (single-tenant runs are bit-identical to the pre-sharding
    /// executor); streams for t > 0 are forked from a seed-derived forker.
    /// Every constructor builds the full vector regardless of which
    /// tenants it owns, so a shard's stream for tenant `t` is identical
    /// to the serial executor's.
    rngs: Vec<Rng>,
    /// One input trace per tenant.
    traces: Vec<Box<dyn Trace>>,
    pub instances: Vec<Instance>,
    by_op: Vec<Vec<usize>>,
    nodes: Vec<NodeState>,
    /// Optional flow routing per pipeline edge: fractions[from_node][to_node].
    route: Vec<Option<Vec<Vec<f64>>>>,
    /// Instances (or SOURCE) blocked on space in each operator's queues.
    waiters: Vec<Vec<usize>>,
    /// Out-/in-edge ids per operator (edge-list order), cached from spec.
    edges_out: Vec<Vec<usize>>,
    edges_in: Vec<Vec<usize>>,
    /// For each join op, which live instance buffers each item id's group.
    join_affinity: Vec<BTreeMap<u64, usize>>,
    /// Join groups stranded while an operator momentarily had no live
    /// instance (e.g. its sole instance relocating between nodes): parked
    /// here instead of dropped, and adopted by the next instance added,
    /// so in-flight sibling partials are never orphaned.
    parked_joins: Vec<BTreeMap<u64, Vec<Option<Item>>>>,
    /// Non-join analogue of `parked_joins`: input records stranded while
    /// their operator momentarily had no live instance (a node failure
    /// under the requeue recovery policy), adopted by the operator's next
    /// instance.  Always empty absent cluster dynamics.
    parked_items: Vec<Vec<Item>>,
    /// Node availability (cluster dynamics).  A down node accepts no
    /// instances; all nodes are up absent a dynamics timeline.
    node_up: Vec<bool>,
    /// Egress-link rate multiplier per node
    /// (`BandwidthDegrade`/`BandwidthRestore`; 1.0 = spec rate).
    bw_factor: Vec<f64>,
    /// Tenant activity (dynamic tenancy): a dormant or departed tenant's
    /// source emits nothing.  All tenants are active absent dynamics.
    tenant_active: Vec<bool>,
    /// Records dropped by node failures, per op (`RecoveryPolicy::Loss`).
    pub lost_records: Vec<u64>,
    /// Distinct lineages killed by node failures, per tenant — the exact
    /// per-tenant loss ledger (a lineage counts once however many of its
    /// replicas/partials are dropped).
    pub lost_items_t: Vec<u64>,
    /// Lineage ids already counted in `lost_items_t`.
    lost_ids: BTreeSet<u64>,
    /// Tombstoned join-group ids per op: a killed lineage's trailing
    /// sibling partials are dropped on arrival instead of opening a group
    /// that can never complete (which would wedge the join forever).
    dead_ids: Vec<BTreeSet<u64>>,
    /// Next lineage id counter per tenant (ids are namespaced by tenant —
    /// see [`encode_item_id`] — so id minting never crosses tenants).
    next_item_id_t: Vec<u64>,
    /// Fixed egress WFQ share per tenant (weights normalized at
    /// construction; 1.0 for a single tenant).  Each tenant's transfers
    /// serialize behind its own sub-link at `share * egress_mbps`.
    egress_share: Vec<f64>,
    /// Per-node CPU-contention denominator, frozen at `run_until` entry
    /// (per-tenant bookings summed in ascending-tenant order, so the
    /// float result is identical however tenants are sharded).  Shared
    /// (`Arc`) so the sharded facade installs one snapshot in K shards
    /// without K heap copies per window.
    frozen_cpu: std::sync::Arc<[f64]>,
    /// Externally supplied contention snapshot for the next window (the
    /// sharded facade gathers bookings across shards); `None` means
    /// recompute from local bookings.
    ext_frozen: Option<std::sync::Arc<[f64]>>,
    op_acc: Vec<OpWindowAcc>,
    /// Lifetime EMA of processed item attrs per op (capacity-oracle input).
    attr_ema: Vec<Option<ItemAttrs>>,
    /// Amplification factors D_i and D_o.  `d_o` is the merged-spec value
    /// (sums sinks across tenants); per-tenant throughput accounting uses
    /// `tenancy.d_o` instead.
    pub d_i: Vec<f64>,
    pub d_o: f64,
    pub items_emitted: u64,
    /// Source items admitted per tenant.
    pub items_emitted_t: Vec<u64>,
    pub out_records: u64,
    /// Records out of each tenant's sinks.
    pub out_records_t: Vec<u64>,
    /// Lifetime records processed per operator (conservation checks).
    pub processed_total: Vec<u64>,
    /// Lifetime records dispatched onto each pipeline edge (fork/join
    /// conservation: replicas count once per edge).
    pub edge_emitted: Vec<u64>,
    out_window_t: Vec<u64>,
    win_start: f64,
    /// Cumulative OOM downtime per op, seconds (Table 6).
    pub oom_downtime_s: Vec<f64>,
    pub oom_events_total: Vec<u32>,
    /// Network transfer latency floor, s.
    net_latency: f64,
    source_done: Vec<bool>,
    /// Previous window's queue-end per op (queue-trend signal).
    prev_q_end: Vec<usize>,
    /// Flight-recorder OOM buffer: `(sim time, op, local instance id)`
    /// per OOM kill, drained by the coordinator each window.  `None`
    /// (tracing off) keeps the hot path to one branch and no allocation.
    trace_ooms: Option<Vec<(f64, u32, u32)>>,
}

impl PipelineSim {
    pub fn new(
        spec: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        seed: u64,
    ) -> Self {
        // Unconditional: an invalid DAG would not panic the executor, it
        // would silently wedge it (see PipelineSpec::validate), so reject
        // it at construction in every build profile.
        if let Err(e) = spec.validate() {
            panic!("invalid pipeline spec '{}': {e}", spec.name);
        }
        let view = TenancyView::single_for(&spec);
        let owned = vec![true; 1];
        Self::new_validated(spec, view, cluster, vec![trace], seed, &owned)
    }

    /// Multi-tenant constructor: host the merged spec's disjoint per-tenant
    /// DAGs (`view`) on shared nodes, one input trace per tenant.
    pub fn new_tenancy(
        spec: PipelineSpec,
        view: TenancyView,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        seed: u64,
    ) -> Self {
        if let Err(e) = spec.validate_with_sources(&view.sources) {
            panic!("invalid merged tenancy spec '{}': {e}", spec.name);
        }
        assert_eq!(traces.len(), view.n_tenants(), "one trace per tenant");
        let owned = vec![true; view.n_tenants()];
        Self::new_validated(spec, view, cluster, traces, seed, &owned)
    }

    /// Shard-member constructor ([`ShardedSim`](crate::sim::ShardedSim)):
    /// identical to [`new_tenancy`](Self::new_tenancy) except that only
    /// tenants with `owned[t] == true` get a source — the others never
    /// emit, never schedule, and are excluded from drain accounting, so a
    /// set of shards whose owned masks partition the tenants processes
    /// exactly the serial executor's event set between them.
    pub fn new_sharded(
        spec: PipelineSpec,
        view: TenancyView,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        seed: u64,
        owned: &[bool],
    ) -> Self {
        if let Err(e) = spec.validate_with_sources(&view.sources) {
            panic!("invalid merged tenancy spec '{}': {e}", spec.name);
        }
        assert_eq!(traces.len(), view.n_tenants(), "one trace per tenant");
        assert_eq!(owned.len(), view.n_tenants(), "one owned flag per tenant");
        Self::new_validated(spec, view, cluster, traces, seed, owned)
    }

    fn new_validated(
        spec: PipelineSpec,
        view: TenancyView,
        cluster: ClusterSpec,
        traces: Vec<Box<dyn Trace>>,
        seed: u64,
        owned: &[bool],
    ) -> Self {
        let n_tenants = view.n_tenants();
        let n_ops = spec.n_ops();
        let n_edges = spec.n_edges();
        let (d_i, d_o) = spec.amplification();
        let edges_out: Vec<Vec<usize>> = (0..n_ops).map(|i| spec.out_edges(i)).collect();
        let edges_in: Vec<Vec<usize>> = (0..n_ops).map(|i| spec.in_edges(i)).collect();
        let nodes = cluster
            .nodes
            .iter()
            .map(|_| NodeState {
                cpu_booked: vec![0.0; n_tenants],
                mem_booked: 0.0,
                accel_booked: 0,
                link_free: vec![0.0; n_tenants],
                egress_mb_window: 0.0,
                join_mb: 0.0,
            })
            .collect();
        let mut engine = Engine::new();
        for t in 0..n_tenants {
            if owned[t] {
                engine.at(0.0, Ev::SourceEmit(t as u32));
            }
        }
        // Stream 0 is the legacy generator; t > 0 fork off a separate
        // seed-derived forker so stream 0's state stays untouched.
        let mut rngs = Vec::with_capacity(n_tenants);
        rngs.push(Rng::new(seed));
        let mut forker = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        for t in 1..n_tenants {
            rngs.push(forker.fork(t as u64));
        }
        // Fixed WFQ egress shares: tenant weight over total (1.0 single
        // tenant, uniform when weights are degenerate).
        let egress_share: Vec<f64> = if n_tenants <= 1 {
            vec![1.0]
        } else {
            let tot: f64 = view.weights.iter().sum();
            if tot > 0.0 {
                view.weights.iter().map(|w| w / tot).collect()
            } else {
                vec![1.0 / n_tenants as f64; n_tenants]
            }
        };
        PipelineSim {
            engine,
            net: TransferNet::new(cluster.nodes.len() * n_tenants.max(1)),
            seed_event_stream: false,
            rngs,
            traces,
            tenancy: view,
            instances: Vec::new(),
            by_op: vec![Vec::new(); n_ops],
            nodes,
            route: vec![None; n_edges],
            waiters: vec![Vec::new(); n_ops],
            edges_out,
            edges_in,
            join_affinity: vec![BTreeMap::new(); n_ops],
            parked_joins: vec![BTreeMap::new(); n_ops],
            parked_items: vec![Vec::new(); n_ops],
            node_up: vec![true; cluster.nodes.len()],
            bw_factor: vec![1.0; cluster.nodes.len()],
            tenant_active: vec![true; n_tenants],
            lost_records: vec![0; n_ops],
            lost_items_t: vec![0; n_tenants],
            lost_ids: BTreeSet::new(),
            dead_ids: vec![BTreeSet::new(); n_ops],
            next_item_id_t: vec![0; n_tenants],
            egress_share,
            frozen_cpu: vec![0.0; cluster.nodes.len()].into(),
            ext_frozen: None,
            op_acc: vec![OpWindowAcc::new(); n_ops],
            attr_ema: vec![None; n_ops],
            d_i,
            d_o,
            items_emitted: 0,
            items_emitted_t: vec![0; n_tenants],
            out_records: 0,
            out_records_t: vec![0; n_tenants],
            processed_total: vec![0; n_ops],
            edge_emitted: vec![0; n_edges],
            out_window_t: vec![0; n_tenants],
            win_start: 0.0,
            oom_downtime_s: vec![0.0; n_ops],
            oom_events_total: vec![0; n_ops],
            net_latency: 1e-3,
            // Non-owned tenants are "done" from birth: they never emit
            // and drain accounting ignores them.
            source_done: (0..n_tenants).map(|t| !owned[t]).collect(),
            prev_q_end: vec![0; n_ops],
            trace_ooms: None,
            spec,
            cluster,
        }
    }

    /// Toggle the flight-recorder OOM buffer (no effect on results: the
    /// buffer is push-only and consumes no RNG).
    pub fn set_trace_ooms(&mut self, on: bool) {
        self.trace_ooms = if on { Some(Vec::new()) } else { None };
    }

    /// Drain buffered `(t, op, local instance id)` OOM kills.
    pub fn take_trace_ooms(&mut self) -> Vec<(f64, u32, u32)> {
        self.trace_ooms.as_mut().map(std::mem::take).unwrap_or_default()
    }

    pub fn now(&self) -> f64 {
        self.engine.now()
    }

    pub fn instances_of(&self, op: usize) -> Vec<usize> {
        self.by_op[op]
            .iter()
            .copied()
            .filter(|&i| self.instances[i].state != InstState::Stopped)
            .collect()
    }

    /// Live (non-draining) instance count per (op, node).
    pub fn placement(&self) -> Vec<Vec<u32>> {
        let mut x = vec![vec![0u32; self.cluster.nodes.len()]; self.spec.n_ops()];
        for inst in &self.instances {
            if matches!(inst.state, InstState::Stopped | InstState::Draining) {
                continue;
            }
            x[inst.op][inst.node] += 1;
        }
        x
    }

    /// Set flow routing for a pipeline edge (id into `spec.edges`).
    pub fn set_route(&mut self, edge: usize, fractions: Option<Vec<Vec<f64>>>) {
        self.route[edge] = fractions;
    }

    /// How many pipeline edges currently carry a routing plan (tests pin
    /// that a placement-aware plan covers every DAG edge).
    pub fn n_routes_set(&self) -> usize {
        self.route.iter().filter(|r| r.is_some()).count()
    }

    // ------------------------------------------------------------------
    // Instance lifecycle
    // ------------------------------------------------------------------

    /// Launch an instance of `op` on `node` with config θ.  Fails if the
    /// node lacks accelerator capacity.
    pub fn add_instance(
        &mut self,
        op: usize,
        node: usize,
        theta: Vec<f64>,
    ) -> Result<usize, SimError> {
        if !self.node_up[node] {
            return Err(SimError::NodeDown { node });
        }
        let tenant = self.tenancy.op_tenant[op];
        let o = &self.spec.operators[op];
        let ns = &mut self.nodes[node];
        let nspec = &self.cluster.nodes[node];
        if o.accels > 0 && ns.accel_booked + o.accels > nspec.accels {
            return Err(SimError::OutOfAccelerators {
                node,
                op: o.name.clone(),
                booked: ns.accel_booked,
                want: o.accels,
                cap: nspec.accels,
            });
        }
        ns.cpu_booked[tenant] += o.cpu;
        ns.mem_booked += o.mem_gb;
        ns.accel_booked += o.accels;
        let now = self.engine.now();
        let id = self.instances.len();
        self.instances.push(Instance {
            op,
            node,
            theta,
            state: InstState::Starting,
            queue: VecDeque::new(),
            pending_out: VecDeque::new(),
            join_buf: BTreeMap::new(),
            batch: Vec::new(),
            batch_service_s: 0.0,
            reserved: 0,
            carry: 0.0,
            conservative: 0,
            config_gen: 0,
            reconfig: None,
            placing: false,
            win: InstWindow::default(),
            win_start: now,
            down_since: Some(now),
            created_at: now,
        });
        self.by_op[op].push(id);
        // Adopt input records stranded while the operator had no live
        // instance (node failure under the requeue recovery policy).
        if !self.parked_items[op].is_empty() {
            let parked = std::mem::take(&mut self.parked_items[op]);
            self.instances[id].queue.extend(parked);
        }
        // Adopt any join groups parked while the operator had no live
        // instance; groups completed in the meantime collapse straight
        // into the queue (processed once this instance is ready).
        if !self.parked_joins[op].is_empty() {
            let parked: Vec<(u64, Vec<Option<Item>>)> =
                std::mem::take(&mut self.parked_joins[op]).into_iter().collect();
            for (gid, slots) in parked {
                if slots.iter().all(Option::is_some) {
                    let merged = merge_group(slots);
                    self.instances[id].queue.push_back(merged);
                } else {
                    let mb: f64 = slots.iter().flatten().map(|it| it.size_mb).sum();
                    self.nodes[node].join_mb += mb;
                    self.instances[id].join_buf.insert(gid, slots);
                    self.join_affinity[op].insert(gid, id);
                }
            }
        }
        self.engine.after(o.start_s, Ev::InstanceReady(InstId::of(id)));
        Ok(id)
    }

    /// Gracefully stop an instance (drains in-flight work first).
    pub fn stop_instance(&mut self, id: usize) {
        let inst = &mut self.instances[id];
        if inst.state == InstState::Stopped {
            return;
        }
        if inst.idle() {
            // Covers Running-idle, Starting, and Restarting (no in-flight
            // batch to drain in any of those states).
            self.finalize_stop(id);
        } else {
            inst.state = InstState::Draining;
        }
    }

    /// Restart an instance with a new configuration (rolling update step).
    /// Applied at the next idle point; incurs `cold_s` downtime.
    pub fn restart_with_config(&mut self, id: usize, theta: Vec<f64>) {
        let inst = &mut self.instances[id];
        if inst.state == InstState::Stopped {
            return;
        }
        inst.reconfig = Some(theta);
        if inst.batch.is_empty() {
            self.apply_reconfig(id);
        }
    }

    fn apply_reconfig(&mut self, id: usize) {
        let now = self.engine.now();
        let cold = self.spec.operators[self.instances[id].op].cold_s;
        let inst = &mut self.instances[id];
        if let Some(theta) = inst.reconfig.take() {
            inst.theta = theta;
            inst.config_gen += 1;
            inst.state = InstState::Restarting;
            if inst.down_since.is_none() {
                inst.down_since = Some(now);
            }
            self.engine.after(cold, Ev::InstanceReady(InstId::of(id)));
        }
    }

    fn finalize_stop(&mut self, id: usize) {
        let (op, node) = (self.instances[id].op, self.instances[id].node);
        // Account trailing downtime.
        let now = self.engine.now();
        {
            let inst = &mut self.instances[id];
            if let Some(d) = inst.down_since.take() {
                inst.win.down_s += now - d.max(inst.win_start);
            }
            inst.state = InstState::Stopped;
        }
        let tenant = self.tenancy.op_tenant[op];
        let o = &self.spec.operators[op];
        let ns = &mut self.nodes[node];
        ns.cpu_booked[tenant] -= o.cpu;
        ns.mem_booked -= o.mem_gb;
        ns.accel_booked -= o.accels;
        // Redistribute any leftover queue items to peers; with no peer
        // left (a failure emptied the op), park them for the next
        // instance instead of dropping.
        let leftovers: Vec<Item> = self.instances[id].queue.drain(..).collect();
        let peers = self.instances_of(op);
        if !peers.is_empty() {
            for (i, item) in leftovers.into_iter().enumerate() {
                let dest = peers[i % peers.len()];
                self.instances[dest].queue.push_back(item);
            }
            for p in &peers {
                self.try_start(*p);
            }
        } else {
            self.parked_items[op].extend(leftovers);
        }
        // Migrate buffered join groups (and their affinity) to a live
        // peer; without peers they are parked for the operator's next
        // instance to adopt (dropping them would orphan in-flight sibling
        // partials and wedge the join forever).
        if !self.instances[id].join_buf.is_empty() {
            let groups: Vec<(u64, Vec<Option<Item>>)> =
                std::mem::take(&mut self.instances[id].join_buf).into_iter().collect();
            let dest = peers
                .iter()
                .copied()
                .min_by_key(|&p| self.instances[p].occupancy());
            for (gid, slots) in groups {
                let mb: f64 = slots.iter().flatten().map(|it| it.size_mb).sum();
                self.nodes[node].join_mb -= mb;
                match dest {
                    Some(d) => {
                        self.nodes[self.instances[d].node].join_mb += mb;
                        self.instances[d].join_buf.insert(gid, slots);
                        self.join_affinity[op].insert(gid, d);
                    }
                    None => {
                        self.join_affinity[op].remove(&gid);
                        self.parked_joins[op].insert(gid, slots);
                    }
                }
            }
        }
        self.wake_waiters(op);
    }

    // ------------------------------------------------------------------
    // Event handling
    // ------------------------------------------------------------------

    /// Run the simulation until `t_end` (absolute seconds).
    ///
    /// Two event stores feed this loop: the engine's heap and the
    /// per-link FIFOs in [`TransferNet`].  Both key entries by
    /// `(time, seq)` drawn from the engine's single counter, so taking
    /// the smaller key at each step replays exactly the total order the
    /// legacy one-heap-event-per-record stream produced — delivery
    /// instants, tie-breaks and all.
    ///
    /// CPU contention is *window-frozen*: the per-node denominator is
    /// snapshotted here (per-tenant bookings summed in ascending-tenant
    /// order) and held for the whole window, so a shard that cannot see
    /// other shards' mid-window bookings computes the exact same
    /// contention the serial executor does.  The sharded facade installs
    /// a cross-shard snapshot via [`set_frozen_cpu`](Self::set_frozen_cpu)
    /// before each window; standalone runs recompute from local bookings.
    pub fn run_until(&mut self, t_end: f64) {
        self.frozen_cpu = self.ext_frozen.take().unwrap_or_else(|| {
            self.nodes.iter().map(|ns| ns.cpu_booked.iter().sum::<f64>()).collect()
        });
        loop {
            let heap = self.engine.peek_key();
            let link = self.net.peek_min();
            let link_first = match (heap, link) {
                (None, None) => break,
                // `<=` matches the heap path's pop condition: events
                // exactly at the horizon belong to this window in both
                // transfer modes.
                (None, Some(l)) => l.0 <= t_end,
                (Some(_), None) => false,
                // Keys are unique (one shared counter), so the tuple
                // comparison is total despite the f64 component.  Beyond
                // the horizon the heap path handles the clock clamp.
                (Some(h), Some(l)) => l < h && l.0 <= t_end,
            };
            if link_first {
                let e = self.net.pop_min();
                self.engine.deliver_external(e.t);
                let item = self.net.take_item(e.slot);
                self.on_transfer(e.dest as usize, e.edge as usize, item);
            } else {
                match self.engine.next_before(t_end) {
                    Some(ev) => self.handle(ev),
                    None => break,
                }
            }
        }
        self.engine.advance_to(t_end);
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::SourceEmit(t) => self.try_source(t as usize),
            Ev::InstanceReady(id) => self.on_ready(id.idx()),
            Ev::BatchDone(id) => self.on_batch_done(id.idx()),
            // Seed-event-stream mode only: the payload still lives in
            // the slab, the event carries its slot.
            Ev::TransferDone { dest, edge, slot } => {
                let item = self.net.take_item(slot);
                self.on_transfer(dest.idx(), edge as usize, item);
            }
        }
    }

    fn on_ready(&mut self, id: usize) {
        let now = self.engine.now();
        let inst = &mut self.instances[id];
        match inst.state {
            InstState::Starting | InstState::Restarting => {
                if let Some(d) = inst.down_since.take() {
                    inst.win.down_s += now - d.max(inst.win_start);
                }
                if inst.state == InstState::Restarting {
                    // leave conservative counter as set by the OOM path
                } else {
                    inst.conservative = 0;
                }
                inst.state = InstState::Running;
                self.try_start(id);
                // A fresh instance frees queue space semantics upstream.
                let op = self.instances[id].op;
                self.wake_waiters(op);
            }
            _ => {}
        }
    }

    fn on_transfer(&mut self, id: usize, edge: usize, item: Item) {
        let inst = &mut self.instances[id];
        inst.reserved = inst.reserved.saturating_sub(1);
        if inst.state == InstState::Stopped {
            // Late arrival to a stopped instance: reroute from the node
            // the item physically landed on.
            let (op, at_node) = (inst.op, inst.node);
            self.redeliver(op, at_node, edge, item);
            return;
        }
        self.deliver(id, edge, item);
    }

    /// Deliver an item that lost its destination (stopped instance) to a
    /// live instance of `op`: the group-affinity holder for buffered join
    /// ids (paying the network when the holder is on another node),
    /// otherwise the least-occupied peer (directly — the legacy
    /// late-arrival shortcut the chain executor has always used).
    fn redeliver(&mut self, op: usize, at_node: usize, edge: usize, item: Item) {
        if let Some(holder) = self.group_holder(op, item.id) {
            self.route_to(at_node, holder, edge, item);
            return;
        }
        let peers = self.instances_of(op);
        if let Some(&dest) = peers.iter().min_by_key(|&&p| self.instances[p].occupancy()) {
            self.deliver(dest, edge, item);
            return;
        }
        // No live instance.  Join partials are parked (an in-flight
        // sibling may already be buffered; dropping would wedge the group
        // forever); non-join items are parked too — reachable when a node
        // failure momentarily leaves the operator with p = 0 — and
        // adopted by the operator's next instance.
        let in_edges = &self.edges_in[op];
        if in_edges.len() > 1 {
            self.park_join_partial(op, edge, item);
        } else {
            self.parked_items[op].push(item);
        }
    }

    /// Park a join partial for `op` (no live instance to buffer it):
    /// slotted into the operator's parked group, dropped against the loss
    /// ledger when its lineage is tombstoned.
    fn park_join_partial(&mut self, op: usize, edge: usize, item: Item) {
        if self.dead_ids[op].contains(&item.id) {
            self.lost_records[op] += 1;
            return;
        }
        let in_edges = &self.edges_in[op];
        let slot = in_edges
            .iter()
            .position(|&e| e == edge)
            .expect("parked edge must enter the destination operator");
        let n_slots = in_edges.len();
        let group = self.parked_joins[op]
            .entry(item.id)
            .or_insert_with(|| vec![None; n_slots]);
        group[slot] = Some(item);
    }

    /// Hand an item arriving on `edge` to instance `id`: straight into the
    /// queue for single-in-edge operators; into the join buffer for joins,
    /// collapsing to one merged queue record when the group completes.
    fn deliver(&mut self, id: usize, edge: usize, item: Item) {
        let op = self.instances[id].op;
        let in_edges = &self.edges_in[op];
        if in_edges.len() <= 1 {
            self.instances[id].queue.push_back(item);
            self.try_start(id);
            return;
        }
        let slot = in_edges
            .iter()
            .position(|&e| e == edge)
            .expect("delivered edge must enter the destination operator");
        let n_slots = in_edges.len();
        let gid = item.id;
        if self.dead_ids[op].contains(&gid) {
            // Sibling of a lineage killed by a node failure (Loss
            // recovery): buffering it would open a group that can never
            // complete.  Drop and ledger it (the lineage itself was
            // already counted once).
            self.lost_records[op] += 1;
            return;
        }
        // Holder re-check at arrival time: a sibling partial may have
        // opened this id's group at another instance while we were in
        // flight (both branches dispatched before either landed).  All
        // partials of a group must meet at one instance; a cross-node
        // forward is a real transfer and pays the egress link.
        if let Some(holder) = self.group_holder(op, gid) {
            if holder != id {
                let from = self.instances[id].node;
                self.route_to(from, holder, edge, item);
                return;
            }
        }
        let node = self.instances[id].node;
        let complete = {
            let inst = &mut self.instances[id];
            let group = inst
                .join_buf
                .entry(gid)
                .or_insert_with(|| vec![None; n_slots]);
            if group[slot].is_none() {
                self.nodes[node].join_mb += item.size_mb;
            } else {
                // Duplicate partial on the same edge (redelivery race):
                // replace, adjusting the accounting.
                self.nodes[node].join_mb += item.size_mb - group[slot].as_ref().unwrap().size_mb;
            }
            group[slot] = Some(item);
            group.iter().all(Option::is_some)
        };
        if complete {
            let slots = self.instances[id].join_buf.remove(&gid).unwrap();
            self.join_affinity[op].remove(&gid);
            let mb: f64 = slots.iter().flatten().map(|it| it.size_mb).sum();
            self.nodes[node].join_mb -= mb;
            let merged = merge_group(slots);
            self.instances[id].queue.push_back(merged);
            // Consuming a group frees join space: upstream may proceed.
            self.wake_waiters(op);
            self.try_start(id);
        } else {
            self.join_affinity[op].insert(gid, id);
        }
    }

    /// Tenant `t`'s source: emit into its source operator's instances.
    /// Unpaced tenants (`source_rate == 0`) emit greedily until admission
    /// blocks (the offline paradigm); paced tenants emit one item per
    /// `1/source_rate` tick.
    fn try_source(&mut self, t: usize) {
        if self.source_done[t] || !self.tenant_active[t] {
            return;
        }
        let src_op = self.tenancy.sources[t];
        let cap = self.spec.operators[src_op].queue_cap;
        let rate = self.tenancy.source_rates[t];
        loop {
            // Find a source-op instance with space.
            let dest = self.by_op[src_op]
                .iter()
                .copied()
                .filter(|&i| self.instances[i].has_space(cap))
                .min_by_key(|&i| self.instances[i].occupancy());
            let Some(dest) = dest else {
                let w = source_waiter(t);
                if !self.waiters[src_op].contains(&w) {
                    self.waiters[src_op].push(w);
                }
                return;
            };
            match self.traces[t].next_item(&mut self.rngs[t]) {
                Some(mut item) => {
                    item.id = encode_item_id(t, self.next_item_id_t[t]);
                    self.next_item_id_t[t] += 1;
                    self.items_emitted += 1;
                    self.items_emitted_t[t] += 1;
                    self.instances[dest].queue.push_back(item);
                    self.try_start(dest);
                    if rate > 0.0 {
                        self.engine.after(1.0 / rate, Ev::SourceEmit(t as u32));
                        return;
                    }
                }
                None => {
                    self.source_done[t] = true;
                    return;
                }
            }
        }
    }

    /// Try to begin a batch on `id`.
    fn try_start(&mut self, id: usize) {
        let cap_mem_mb = {
            let inst = &self.instances[id];
            self.cluster.nodes[inst.node].accel_mem_mb
        };
        let now = self.engine.now();
        let inst = &self.instances[id];
        if inst.state != InstState::Running {
            return;
        }
        // Mid-placement the pending_out check below would read the
        // temporarily-taken (empty) deque and start a batch past the
        // blocked-output backpressure bound; the frame's caller re-tries.
        if inst.placing {
            return;
        }
        if !inst.batch.is_empty() || !inst.pending_out.is_empty() || inst.queue.is_empty() {
            return;
        }
        let op_idx = inst.op;
        let tenant = self.tenancy.op_tenant[op_idx];
        let op = &self.spec.operators[op_idx];

        // Sample queue length for backlog signals.
        let qlen = inst.queue.len();

        // Form the batch.  A post-OOM recovery phase runs with a halved
        // config (vLLM-style preemption/recompute after an OOM abort);
        // the common path borrows θ in place — no per-batch clone.
        let halved: Option<Vec<f64>> = (inst.conservative > 0).then(|| {
            let mut t = inst.theta.clone();
            if !t.is_empty() {
                t[0] = (t[0] / 2.0).max(1.0);
            }
            if t.len() > 1 {
                t[1] = (t[1] / 2.0).max(256.0);
            }
            t
        });
        let theta_eff: &[f64] = halved.as_deref().unwrap_or(&inst.theta);
        let batch_n = match op.kind {
            OperatorKind::CpuSync => 1,
            OperatorKind::AccelAsync => {
                service::accel_eff_batch(theta_eff).min(inst.queue.len()).max(1)
            }
        };

        let inst = &mut self.instances[id];
        inst.win.q_sum += qlen as f64;
        inst.win.q_n += 1;
        let items: Vec<Item> = inst.queue.drain(..batch_n).collect();
        if inst.conservative > 0 {
            inst.conservative -= 1;
        }

        // Service time + memory check (θ re-borrowed after the queue
        // drain; `halved` is an owned local, so it survives).
        let inst = &self.instances[id];
        let theta_eff: &[f64] = halved.as_deref().unwrap_or(&inst.theta);
        let (service_s, oom, peak_mem) = match op.kind {
            OperatorKind::CpuSync => {
                let contention = {
                    let cores = self.cluster.nodes[inst.node].cpu_cores;
                    (cores / self.frozen_cpu[inst.node].max(1e-9)).min(1.0)
                };
                let t = service::cpu_record_time(
                    &op.service,
                    &items[0].attrs,
                    &mut self.rngs[tenant],
                ) / contention;
                (t, false, None)
            }
            OperatorKind::AccelAsync => {
                let stats = service::BatchStats::of(
                    &items.iter().map(|i| i.attrs).collect::<Vec<_>>(),
                );
                let mem = service::accel_batch_mem(
                    &op.service,
                    theta_eff,
                    stats,
                    &mut self.rngs[tenant],
                );
                if mem > cap_mem_mb {
                    (0.0, true, Some(mem))
                } else {
                    (
                        service::accel_batch_time(
                            &op.service,
                            theta_eff,
                            stats,
                            &mut self.rngs[tenant],
                        ),
                        false,
                        Some(mem),
                    )
                }
            }
        };

        let cold = op.cold_s;
        let inst = &mut self.instances[id];
        if let Some(mem) = peak_mem {
            inst.win.peak_mem_mb = inst.win.peak_mem_mb.max(mem);
        }
        if oom {
            // OOM: items return to the queue; instance restarts cold.
            for item in items.into_iter().rev() {
                inst.queue.push_front(item);
            }
            inst.win.oom_events += 1;
            inst.state = InstState::Restarting;
            inst.down_since = Some(now);
            inst.conservative = 4;
            self.oom_events_total[op_idx] += 1;
            self.oom_downtime_s[op_idx] += cold;
            self.engine.after(cold, Ev::InstanceReady(InstId::of(id)));
            if let Some(buf) = self.trace_ooms.as_mut() {
                buf.push((now, op_idx as u32, id as u32));
            }
            return;
        }
        inst.batch = items;
        inst.batch_service_s = service_s;
        self.engine.after(service_s, Ev::BatchDone(InstId::of(id)));
    }

    fn on_batch_done(&mut self, id: usize) {
        if self.instances[id].state == InstState::Stopped {
            // The instance died (node failure) with this batch in flight;
            // its items were already requeued or counted lost.
            return;
        }
        let op_idx = self.instances[id].op;
        let tenant = self.tenancy.op_tenant[op_idx];
        // Hot path (runs once per finished batch): copy the four scalar
        // fields used below instead of cloning the whole OperatorSpec
        // (name, config space, service model, …).
        let (features, fanout, child_scale, out_mb) = {
            let o = &self.spec.operators[op_idx];
            (o.features, o.fanout, o.child_scale, o.out_mb)
        };
        let is_sink = self.edges_out[op_idx].is_empty();

        // Account the batch.
        let items: Vec<Item> = {
            let inst = &mut self.instances[id];
            let items = std::mem::take(&mut inst.batch);
            inst.win.records_done += items.len() as u64;
            inst.win.batches_done += 1;
            inst.win.busy_s += inst.batch_service_s;
            items
        };
        self.processed_total[op_idx] += items.len() as u64;
        self.op_acc[op_idx].records_in += items.len() as u64;
        for item in &items {
            let mut r = self.rngs[tenant].fork(7);
            self.op_acc[op_idx].observe(item, features, &mut r);
            // Lifetime attr EMA (capacity-oracle input).
            let ema = &mut self.attr_ema[op_idx];
            let a = item.attrs;
            *ema = Some(match ema {
                None => a,
                Some(e) => ItemAttrs {
                    tokens_in: e.tokens_in * 0.99 + a.tokens_in * 0.01,
                    tokens_out: e.tokens_out * 0.99 + a.tokens_out * 0.01,
                    pixels_m: e.pixels_m * 0.99 + a.pixels_m * 0.01,
                    frames: e.frames * 0.99 + a.frames * 0.01,
                },
            });
        }

        // Fanout into children.  A single child inherits its parent's
        // lineage id (joins downstream align on it); a genuine split
        // mints fresh ids — each child is a new lineage root.
        let mut outputs: Vec<Item> = Vec::new();
        {
            let inst = &mut self.instances[id];
            for item in &items {
                inst.carry += fanout;
                let k = inst.carry.floor() as usize;
                inst.carry -= k as f64;
                for c in 0..k {
                    let a = item.attrs;
                    let s = child_scale;
                    let child_id = if k == 1 {
                        item.id
                    } else {
                        encode_item_id(tenant, self.next_item_id_t[tenant] + c as u64)
                    };
                    outputs.push(Item {
                        id: child_id,
                        attrs: ItemAttrs {
                            tokens_in: a.tokens_in * s[0],
                            tokens_out: a.tokens_out * s[1],
                            pixels_m: a.pixels_m * s[2],
                            frames: a.frames * s[3],
                        },
                        size_mb: out_mb * self.rngs[tenant].lognormal(0.0, 0.15),
                        regime: item.regime,
                    });
                }
                if k > 1 {
                    self.next_item_id_t[tenant] += k as u64;
                }
            }
        }

        if is_sink {
            self.out_records += outputs.len() as u64;
            self.out_records_t[tenant] += outputs.len() as u64;
            self.out_window_t[tenant] += outputs.len() as u64;
        } else {
            // Replicate each child onto every out-edge (fork semantics;
            // a chain op has exactly one out-edge).
            let inst = &mut self.instances[id];
            for child in outputs {
                for &e in &self.edges_out[op_idx] {
                    inst.pending_out.push_back((e, child));
                    self.edge_emitted[e] += 1;
                }
            }
        }

        // Space freed in our queue: wake upstream.
        self.wake_waiters(op_idx);

        // Apply a pending reconfig at this idle point.
        if self.instances[id].reconfig.is_some() && self.instances[id].pending_out.is_empty() {
            self.apply_reconfig(id);
            return;
        }

        self.try_place_outputs(id);
        let inst = &self.instances[id];
        if inst.state == InstState::Draining && inst.idle() {
            // In-flight work done and outputs placed: release (leftover
            // queue items are redistributed by finalize_stop).
            self.finalize_stop(id);
            return;
        }
        self.try_start(id);
    }

    /// Push pending outputs downstream; block per edge on full queues.
    /// Per-edge (not head-of-line) blocking: a branch whose destination is
    /// full must not starve its sibling branch, or a fork/join pair could
    /// deadlock with the join waiting on exactly the starved branch.
    fn try_place_outputs(&mut self, id: usize) {
        if self.edges_out[self.instances[id].op].is_empty() {
            return;
        }
        if self.instances[id].placing {
            // A frame for this instance is already on the stack (a join
            // completion we triggered cascaded back here); it will finish
            // the placement itself.
            return;
        }
        self.instances[id].placing = true;
        let from_node = self.instances[id].node;
        let pending = std::mem::take(&mut self.instances[id].pending_out);
        let mut kept: VecDeque<(usize, Item)> = VecDeque::new();
        let mut blocked: Vec<usize> = Vec::new();
        for (edge, item) in pending {
            if blocked.contains(&edge) {
                // The always-admit rule must still reach partials of
                // already-buffered join groups even behind a blocked edge
                // head — with several instances per branch running out of
                // order, the group-completing partial can sit behind a
                // no-holder one, and keeping it would wedge the join
                // (overtaking is safe: joins order by id, not arrival).
                let dst_op = self.spec.edges[edge].1;
                if let Some(holder) = self.group_holder(dst_op, item.id) {
                    self.dispatch(id, holder, edge, item);
                    continue;
                }
                kept.push_back((edge, item));
                continue;
            }
            let dst_op = self.spec.edges[edge].1;
            let cap = self.spec.operators[dst_op].queue_cap;
            match self.pick_dest(edge, from_node, cap, &item) {
                Some(dest) => self.dispatch(id, dest, edge, item),
                None => {
                    blocked.push(edge);
                    if !self.waiters[dst_op].contains(&id) {
                        self.waiters[dst_op].push(id);
                    }
                    kept.push_back((edge, item));
                }
            }
        }
        self.instances[id].pending_out = kept;
        self.instances[id].placing = false;
        // Fully drained: if a reconfig is pending and we're idle, apply it.
        if self.instances[id].pending_out.is_empty()
            && self.instances[id].batch.is_empty()
            && self.instances[id].reconfig.is_some()
        {
            self.apply_reconfig(id);
        }
    }

    /// Pick a destination instance for `edge` from `from_node`, honouring
    /// the flow plan when present.  Partials of a join group already
    /// buffered are pinned to the buffering instance and always admitted
    /// (completing a group frees space — the deadlock-freedom rule).
    fn pick_dest(&mut self, edge: usize, from_node: usize, cap: usize, item: &Item) -> Option<usize> {
        let next = self.spec.edges[edge].1;
        if let Some(holder) = self.group_holder(next, item.id) {
            return Some(holder);
        }
        if let Some(w) = &self.route[edge] {
            let weights = &w[from_node];
            if weights.iter().sum::<f64>() > 1e-9 {
                let l = self.rngs[self.tenancy.op_tenant[next]].categorical(weights);
                // Least-occupied instance with space on the sampled node.
                let best = self.by_op[next]
                    .iter()
                    .copied()
                    .filter(|&i| self.instances[i].node == l && self.instances[i].has_space(cap))
                    .min_by_key(|&i| self.instances[i].occupancy());
                if best.is_some() {
                    return best;
                }
            }
        }
        // Fallback / no plan: least-occupied anywhere (local first on tie).
        self.by_op[next]
            .iter()
            .copied()
            .filter(|&i| self.instances[i].has_space(cap))
            .min_by_key(|&i| {
                (self.instances[i].occupancy(), (self.instances[i].node != from_node) as usize)
            })
    }

    /// The join-group holder rule, single definition point: the live
    /// instance already buffering `item_id`'s group at join `op`, if any.
    /// Partials are always routed there and always admitted — completing
    /// a group frees space (the deadlock-freedom rule).
    fn group_holder(&self, op: usize, item_id: u64) -> Option<usize> {
        if self.edges_in[op].len() <= 1 {
            return None;
        }
        let &h = self.join_affinity[op].get(&item_id)?;
        (self.instances[h].state != InstState::Stopped).then_some(h)
    }

    /// Move one item from `src` to destination instance `dest` along
    /// `edge`: directly for same-node, serialized behind the egress link
    /// for cross-node.
    fn dispatch(&mut self, src: usize, dest: usize, edge: usize, item: Item) {
        let from_node = self.instances[src].node;
        self.route_to(from_node, dest, edge, item);
    }

    /// Physical routing from a node to a destination instance: direct
    /// delivery on the same node, a real transfer (egress link + latency
    /// + reservation) across nodes.
    fn route_to(&mut self, from_node: usize, dest: usize, edge: usize, item: Item) {
        if self.instances[dest].node == from_node {
            self.deliver(dest, edge, item);
        } else {
            self.send(from_node, dest, edge, item);
        }
    }

    /// Cross-node transfer: serialize behind the sending tenant's egress
    /// sub-link on `from_node` and reserve queue space at the destination.
    /// Used both for planned dispatches and for forwarding join partials
    /// to their group's holding instance — a forward is a real transfer
    /// and pays the same network cost.
    ///
    /// Each tenant owns a fixed WFQ share of the node's egress
    /// (`egress_share`, 1.0 for a single tenant): its transfers serialize
    /// behind its own sub-link at the scaled rate and never read another
    /// tenant's link horizon — the decoupling that lets shards send
    /// without synchronizing.  Non-work-conserving by design: an idle
    /// tenant's share is not lent out (documented in DESIGN.md).
    fn send(&mut self, from_node: usize, dest: usize, edge: usize, item: Item) {
        let now = self.engine.now();
        let tenant = self.tenancy.op_tenant[self.spec.edges[edge].0];
        let rate = (self.cluster.nodes[from_node].egress_mbps
            * self.bw_factor[from_node]
            * self.egress_share[tenant])
            .max(1.0);
        let ns = &mut self.nodes[from_node];
        ns.egress_mb_window += item.size_mb;
        let start = ns.link_free[tenant].max(now);
        let arrive = start + item.size_mb / rate + self.net_latency;
        ns.link_free[tenant] = arrive;
        self.instances[dest].reserved += 1;
        // The payload is parked in the slab either way; only the *key*
        // travels.  Both branches draw the sequence number from the same
        // counter at the same program point, so tie-breaks are identical
        // across modes.
        let slot = self.net.put_item(item);
        if self.seed_event_stream {
            self.engine.at(
                arrive,
                Ev::TransferDone { dest: InstId::of(dest), edge: edge as u32, slot },
            );
        } else {
            let seq = self.engine.alloc_seq();
            let link = from_node * self.tenancy.n_tenants() + tenant;
            self.net.enqueue(
                link,
                LinkEntry { t: arrive, seq, dest: InstId::of(dest).0, edge: edge as u32, slot },
            );
        }
    }

    fn wake_waiters(&mut self, op: usize) {
        let ws = std::mem::take(&mut self.waiters[op]);
        for w in ws {
            if SOURCE - w < self.traces.len() {
                // A blocked tenant source (sentinel `SOURCE - t`).
                self.try_source(SOURCE - w);
            } else if self.instances[w].placing {
                // Mid-placement up the stack (we got here via one of its
                // own dispatches): keep the registration — its pending_out
                // is taken, so acting now would misread it as idle.  The
                // consumer that freed this space will wake again.
                if !self.waiters[op].contains(&w) {
                    self.waiters[op].push(w);
                }
            } else {
                self.try_place_outputs(w);
                if self.instances[w].state == InstState::Draining && self.instances[w].idle() {
                    self.finalize_stop(w);
                } else {
                    self.try_start(w);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Cluster dynamics: node churn, dynamic tenancy, bandwidth shifts
    // ------------------------------------------------------------------

    /// Node availability map (true = up).
    pub fn nodes_up(&self) -> &[bool] {
        &self.node_up
    }

    /// Tenant activity map (true = source offers load).
    pub fn tenants_active(&self) -> &[bool] {
        &self.tenant_active
    }

    /// Total records dropped by node failures so far
    /// (`RecoveryPolicy::Loss`; 0 under `Requeue`).
    pub fn lost_records_total(&self) -> u64 {
        self.lost_records.iter().sum()
    }

    /// Ops with any non-stopped instance (including Draining — a failure
    /// kills those too) on `node`: the sample-invalidation set for
    /// topology events on that node.
    pub fn ops_on_node(&self, node: usize) -> Vec<usize> {
        let mut seen = vec![false; self.spec.n_ops()];
        for inst in &self.instances {
            if inst.node == node && inst.state != InstState::Stopped {
                seen[inst.op] = true;
            }
        }
        (0..self.spec.n_ops()).filter(|&i| seen[i]).collect()
    }

    /// Bring a node (back) up.  Its capacity returns empty — the next
    /// scheduling round re-places instances there.
    pub fn set_node_up(&mut self, node: usize) {
        self.node_up[node] = true;
    }

    /// Scale a node's egress-link rate (`BandwidthDegrade`/`Restore`).
    pub fn set_bandwidth_factor(&mut self, node: usize, factor: f64) {
        self.bw_factor[node] = factor;
    }

    /// Splice a tenant's source in or out mid-run.  Activation re-arms
    /// the source immediately; deactivation stops new admissions while
    /// already-admitted items keep draining.
    pub fn set_tenant_active(&mut self, t: usize, active: bool) {
        if self.tenant_active[t] == active {
            return;
        }
        self.tenant_active[t] = active;
        if active && !self.source_done[t] {
            self.engine.after(0.0, Ev::SourceEmit(t as u32));
        }
    }

    /// Crash a node: mark it down and kill every instance on it
    /// *immediately* (no drain — unlike [`stop_instance`]).  What happens
    /// to the in-flight records is the recovery policy's call:
    ///
    /// * `requeue = true` — surviving records re-enter the pipeline at
    ///   the operator they were lost at (the lineage-re-execution
    ///   shortcut; re-injection pays no network).  Join groups migrate to
    ///   a live peer or park, exactly like a graceful stop.  Per-tenant
    ///   conservation stays exact and nothing is counted lost.
    /// * `requeue = false` (loss) — queue, batch, blocked outputs, and
    ///   buffered join groups are dropped and ledgered per op
    ///   ([`lost_records`](Self::lost_records)) and once per killed
    ///   lineage per tenant ([`lost_items_t`](Self::lost_items_t));
    ///   killed lineages are tombstoned at the tenant's joins so trailing
    ///   sibling partials are dropped on arrival instead of wedging the
    ///   join.
    ///
    /// Transfers already on the wire survive either way: they arrive at
    /// the stopped instance and reroute to a live peer (or park).
    /// Returns the records dropped by this event.
    ///
    /// [`stop_instance`]: Self::stop_instance
    /// [`lost_records`]: Self::lost_records
    /// [`lost_items_t`]: Self::lost_items_t
    pub fn fail_node(&mut self, node: usize, requeue: bool) -> u64 {
        self.node_up[node] = false;
        let lost_before: u64 = self.lost_records.iter().sum();
        let now = self.engine.now();
        let victims: Vec<usize> = (0..self.instances.len())
            .filter(|&i| {
                self.instances[i].node == node && self.instances[i].state != InstState::Stopped
            })
            .collect();
        for id in victims {
            let op = self.instances[id].op;
            // Strip the instance bare, then mark it stopped and release
            // its bookings (the node is down, but the books must balance
            // for when it recovers).
            let (queue, batch, pending, joins) = {
                let inst = &mut self.instances[id];
                inst.reconfig = None;
                if let Some(d) = inst.down_since.take() {
                    inst.win.down_s += now - d.max(inst.win_start);
                }
                inst.state = InstState::Stopped;
                (
                    inst.queue.drain(..).collect::<Vec<Item>>(),
                    std::mem::take(&mut inst.batch),
                    inst.pending_out.drain(..).collect::<Vec<(usize, Item)>>(),
                    std::mem::take(&mut inst.join_buf).into_iter().collect::<Vec<_>>(),
                )
            };
            let tenant = self.tenancy.op_tenant[op];
            let o = &self.spec.operators[op];
            let ns = &mut self.nodes[node];
            ns.cpu_booked[tenant] -= o.cpu;
            ns.mem_booked -= o.mem_gb;
            ns.accel_booked -= o.accels;
            for (_, slots) in &joins {
                let mb: f64 = slots.iter().flatten().map(|it| it.size_mb).sum();
                ns.join_mb -= mb;
            }
            if requeue {
                for item in queue.into_iter().chain(batch) {
                    self.requeue_input(op, item);
                }
                for (edge, item) in pending {
                    self.recover_in_flight(edge, item);
                }
                // Buffered join groups migrate to a live peer or park —
                // the same never-orphan rule as a graceful stop.
                let peers = self.instances_of(op);
                let dest =
                    peers.iter().copied().min_by_key(|&p| self.instances[p].occupancy());
                for (gid, slots) in joins {
                    match dest {
                        Some(d) => {
                            let mb: f64 =
                                slots.iter().flatten().map(|it| it.size_mb).sum();
                            self.nodes[self.instances[d].node].join_mb += mb;
                            self.instances[d].join_buf.insert(gid, slots);
                            self.join_affinity[op].insert(gid, d);
                        }
                        None => {
                            self.join_affinity[op].remove(&gid);
                            self.parked_joins[op].insert(gid, slots);
                        }
                    }
                }
            } else {
                for item in queue.into_iter().chain(batch) {
                    self.kill_record(op, &item);
                }
                for (_, item) in pending {
                    self.kill_record(op, &item);
                }
                for (gid, slots) in joins {
                    self.join_affinity[op].remove(&gid);
                    self.lost_records[op] += slots.iter().flatten().count() as u64;
                    self.kill_lineage(self.tenancy.op_tenant[op], gid);
                }
            }
            self.wake_waiters(op);
        }
        self.lost_records.iter().sum::<u64>() - lost_before
    }

    /// Re-inject a recovered input record at `op`: the least-occupied
    /// live instance takes it (admission caps waived for recovery — the
    /// record already held queue space before the crash), or it parks for
    /// the operator's next instance.
    fn requeue_input(&mut self, op: usize, item: Item) {
        let dest = self
            .instances_of(op)
            .into_iter()
            .min_by_key(|&p| self.instances[p].occupancy());
        match dest {
            Some(d) => {
                self.instances[d].queue.push_back(item);
                self.try_start(d);
            }
            None => self.parked_items[op].push(item),
        }
    }

    /// Re-inject a recovered blocked output along its pipeline edge:
    /// join partials go to their group's holder, everything else to the
    /// least-occupied live downstream instance, else parks.
    fn recover_in_flight(&mut self, edge: usize, item: Item) {
        let dst = self.spec.edges[edge].1;
        if let Some(holder) = self.group_holder(dst, item.id) {
            self.deliver(holder, edge, item);
            return;
        }
        let dest = self
            .instances_of(dst)
            .into_iter()
            .min_by_key(|&p| self.instances[p].occupancy());
        match dest {
            Some(d) => self.deliver(d, edge, item),
            None => {
                if self.edges_in[dst].len() > 1 {
                    self.park_join_partial(dst, edge, item);
                } else {
                    self.parked_items[dst].push(item);
                }
            }
        }
    }

    /// Ledger a record dropped at `op` and kill its lineage.
    fn kill_record(&mut self, op: usize, item: &Item) {
        self.lost_records[op] += 1;
        self.kill_lineage(self.tenancy.op_tenant[op], item.id);
    }

    /// Kill a lineage: count it once for its tenant, tombstone the id at
    /// every join of the tenant, and drop any sibling partials it
    /// already buffered (a group missing a dead sibling could never
    /// complete — it would pin memory and wedge the join forever).
    /// Removing a group from a *live* holder frees join admission space,
    /// so that join's blocked upstream producers are woken.
    fn kill_lineage(&mut self, tenant: usize, id: u64) {
        if self.lost_ids.insert(id) {
            self.lost_items_t[tenant] += 1;
        }
        for j in 0..self.spec.n_ops() {
            if self.tenancy.op_tenant[j] != tenant || self.edges_in[j].len() <= 1 {
                continue;
            }
            self.dead_ids[j].insert(id);
            if let Some(h) = self.join_affinity[j].remove(&id) {
                if let Some(slots) = self.instances[h].join_buf.remove(&id) {
                    let mb: f64 = slots.iter().flatten().map(|it| it.size_mb).sum();
                    self.nodes[self.instances[h].node].join_mb -= mb;
                    self.lost_records[j] += slots.iter().flatten().count() as u64;
                    if self.instances[h].state != InstState::Stopped {
                        self.wake_waiters(j);
                    }
                }
            }
            if let Some(slots) = self.parked_joins[j].remove(&id) {
                self.lost_records[j] += slots.iter().flatten().count() as u64;
            }
        }
    }

    // ------------------------------------------------------------------
    // Metrics & oracles
    // ------------------------------------------------------------------

    /// Flush the metrics window: per-operator snapshots + per-tenant
    /// output records this window.  Resets window accumulators.
    /// Equivalent to [`window_metrics`](Self::window_metrics) followed by
    /// [`close_window`](Self::close_window) — the sharded facade runs the
    /// pure half inside each shard's tick task and only the reset half on
    /// the merge path.
    pub fn flush_metrics(&mut self) -> (Vec<OpMetrics>, Vec<u64>) {
        let snap = self.window_metrics();
        self.close_window();
        snap
    }

    /// The window's per-operator snapshots + per-tenant outputs *without*
    /// closing the window — a pure read, so a shard can publish it from
    /// its own tick task (overlapped with other shards' ticks) and the
    /// facade can still fall back to a full [`flush_metrics`](Self::flush_metrics)
    /// if the publish went stale.  Identical values either way.
    pub fn window_metrics(&self) -> (Vec<OpMetrics>, Vec<u64>) {
        let now = self.engine.now();
        let window_s = (now - self.win_start).max(1e-9);
        let mut out = Vec::with_capacity(self.spec.n_ops());
        for op in 0..self.spec.n_ops() {
            let mut records = 0u64;
            let mut busy = 0.0;
            let mut active = 0.0;
            let mut peak_mem: f64 = 0.0;
            let mut ooms = 0u32;
            let mut q_end = 0usize;
            let mut q_sum = 0.0;
            let mut q_n = 0u64;
            let mut n_active = 0usize;
            let mut per_instance = Vec::new();
            for &i in &self.by_op[op] {
                let inst = &self.instances[i];
                if inst.state == InstState::Stopped {
                    continue;
                }
                let start = inst.win_start.max(inst.created_at);
                let mut down = inst.win.down_s;
                if let Some(d) = inst.down_since {
                    down += now - d.max(start);
                }
                let a = (now - start - down).max(0.0);
                records += inst.win.records_done;
                busy += inst.win.busy_s;
                active += a;
                peak_mem = peak_mem.max(inst.win.peak_mem_mb);
                ooms += inst.win.oom_events;
                // Join backlog (incomplete groups) is queue pressure too.
                q_end += inst.queue.len() + inst.join_buf.len();
                q_sum += inst.win.q_sum;
                q_n += inst.win.q_n;
                if a > 0.0 {
                    n_active += 1;
                }
                per_instance.push(InstanceMetrics {
                    inst: i,
                    node: inst.node,
                    records: inst.win.records_done,
                    busy_s: inst.win.busy_s,
                    active_s: a,
                    peak_mem_mb: inst.win.peak_mem_mb,
                    oom_events: inst.win.oom_events,
                    queue_len: inst.queue.len() + inst.join_buf.len(),
                    config_gen: inst.config_gen,
                });
            }
            let acc = &self.op_acc[op];
            let (feat_mean, feat_std) = acc.mean_std();
            let q_begin = self
                .prev_q_end
                .get(op)
                .copied()
                .unwrap_or(0);
            out.push(OpMetrics {
                op,
                window_s,
                records_in: acc.records_in,
                records_out: records,
                rate_per_inst: if active > 0.0 { records as f64 / (active / n_active.max(1) as f64) / n_active.max(1) as f64 } else { 0.0 },
                utilization: if active > 0.0 { (busy / active).min(1.0) } else { 0.0 },
                queue_begin: q_begin,
                queue_end: q_end,
                queue_avg: if q_n > 0 { q_sum / q_n as f64 } else { q_end as f64 },
                feat_mean,
                feat_std,
                peak_mem_mb: peak_mem,
                oom_events: ooms,
                n_active,
                cluster_samples: acc.reservoir.clone(),
                per_instance,
            });
        }
        (out, self.out_window_t.clone())
    }

    /// Close the metrics window: reset every window accumulator exactly
    /// as the tail of the old monolithic flush did, without recomputing
    /// the snapshot.  The facade pairs this with a shard's published
    /// [`window_metrics`](Self::window_metrics) so the serial inter-window
    /// work is O(reset), not O(recompute).
    pub fn close_window(&mut self) {
        let now = self.engine.now();
        let mut q_ends = Vec::with_capacity(self.spec.n_ops());
        for op in 0..self.spec.n_ops() {
            // Queue-end recomputed from live state (identical to the
            // snapshot's value: nothing ran between the two).
            let mut q_end = 0usize;
            for &i in &self.by_op[op] {
                let inst = &mut self.instances[i];
                if inst.state == InstState::Stopped {
                    continue;
                }
                q_end += inst.queue.len() + inst.join_buf.len();
                inst.win.reset();
                inst.win_start = now;
            }
            q_ends.push(q_end);
            // Clears the reservoir too (the old flush `take`d it).
            self.op_acc[op].reset();
        }
        // Record queue-end as next window's queue-begin.
        self.prev_q_end = q_ends;
        for ns in &mut self.nodes {
            ns.egress_mb_window = 0.0;
        }
        self.out_window_t = vec![0; self.tenancy.n_tenants()];
        self.win_start = now;
    }

    /// Ground-truth sustainable per-instance rate for `op` under config θ
    /// and the currently observed workload (isolated-profiling oracle —
    /// evaluation only, never fed to the scheduler).
    pub fn true_unit_rate(&self, op: usize, theta: &[f64]) -> f64 {
        let attrs = self.attr_ema[op].unwrap_or(ItemAttrs {
            tokens_in: 512.0,
            tokens_out: 64.0,
            pixels_m: 1.0,
            frames: 1.0,
        });
        service::true_unit_rate(&self.spec.operators[op].service, theta, &attrs)
    }

    /// Current mean attrs seen by `op` (oracle input for benches).
    pub fn mean_attrs(&self, op: usize) -> Option<ItemAttrs> {
        self.attr_ema[op]
    }

    /// Aggregate throughput in original-input records/s over the whole
    /// run: the sum of per-tenant throughputs (identical to the classic
    /// `out_records / D_o / t` for a single tenant).
    pub fn avg_throughput(&self) -> f64 {
        if self.now() <= 0.0 {
            return 0.0;
        }
        (0..self.tenancy.n_tenants()).map(|t| self.tenant_throughput(t)).sum()
    }

    /// Tenant `t`'s throughput in its own input records/s.
    pub fn tenant_throughput(&self, t: usize) -> f64 {
        if self.now() <= 0.0 {
            return 0.0;
        }
        (self.out_records_t[t] as f64 / self.tenancy.d_o[t]) / self.now()
    }

    /// Route future cross-node transfers through the legacy
    /// one-heap-event-per-record stream instead of the batched link
    /// FIFOs.  Used by the perf bench as the measured baseline and by
    /// the parity tests as the reference; both modes draw `(time, seq)`
    /// keys from the same counter and are bit-identical by construction.
    pub fn set_seed_event_stream(&mut self, on: bool) {
        self.seed_event_stream = on;
    }

    /// Install the CPU-contention snapshot for the *next* window (used by
    /// the sharded facade, which gathers per-(node, tenant) bookings
    /// across all shards and sums them in ascending-tenant order —
    /// bit-identical to the serial executor's own snapshot).  One `Arc`
    /// is shared by every shard.
    pub fn set_frozen_cpu(&mut self, frozen: std::sync::Arc<[f64]>) {
        debug_assert_eq!(frozen.len(), self.nodes.len());
        self.ext_frozen = Some(frozen);
    }

    /// Accelerator slots currently booked on `node` (facade admission).
    pub fn node_accel_booked(&self, node: usize) -> u32 {
        self.nodes[node].accel_booked
    }

    /// CPU cores booked by `tenant`'s instances on `node` (facade
    /// contention gather).
    pub fn node_cpu_booked(&self, node: usize, tenant: usize) -> f64 {
        self.nodes[node].cpu_booked[tenant]
    }

    /// Copy `tenant`'s per-node CPU bookings into `out` (len = node
    /// count).  The sharded facade's tick tasks use this to publish a
    /// dense row per owned tenant so the next window's frozen-CPU gather
    /// is a fold over published buffers instead of a post-barrier pass.
    pub fn copy_cpu_booked(&self, tenant: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.nodes.len());
        for (slot, ns) in out.iter_mut().zip(&self.nodes) {
            *slot = ns.cpu_booked[tenant];
        }
    }

    /// High-water mark of live entries in the event heap.
    pub fn peak_heap_entries(&self) -> usize {
        self.engine.peak_entries()
    }

    /// High-water mark of simultaneous in-flight cross-node transfers.
    pub fn peak_in_flight_transfers(&self) -> usize {
        self.net.peak_in_flight()
    }

    /// True when every trace is exhausted and no work remains in flight —
    /// queues, batches, blocked outputs, buffered join partials, and
    /// records still crossing the network (`reserved` transfers).
    pub fn drained(&self) -> bool {
        self.source_done
            .iter()
            .zip(&self.tenant_active)
            .all(|(&d, &active)| d || !active)
            && self.parked_joins.iter().all(BTreeMap::is_empty)
            && self.parked_items.iter().all(Vec::is_empty)
            && self.instances.iter().all(|i| {
                i.reserved == 0
                    && (i.state == InstState::Stopped
                        || (i.idle() && i.queue.is_empty() && i.join_buf.is_empty()))
            })
    }

    /// Per-tenant [`drained`](Self::drained): tenant `t`'s trace is
    /// exhausted and none of *its* operators hold in-flight work (other
    /// tenants may still be running).
    pub fn tenant_drained(&self, t: usize) -> bool {
        (self.source_done[t] || !self.tenant_active[t])
            && self
                .parked_joins
                .iter()
                .enumerate()
                .all(|(op, p)| self.tenancy.op_tenant[op] != t || p.is_empty())
            && self
                .parked_items
                .iter()
                .enumerate()
                .all(|(op, p)| self.tenancy.op_tenant[op] != t || p.is_empty())
            && self.instances.iter().all(|i| {
                self.tenancy.op_tenant[i.op] != t
                    || (i.reserved == 0
                        && (i.state == InstState::Stopped
                            || (i.idle() && i.queue.is_empty() && i.join_buf.is_empty())))
            })
    }

    /// Egress MB sent by each node in the current window (network metric).
    pub fn egress_window_mb(&self) -> Vec<f64> {
        self.nodes.iter().map(|n| n.egress_mb_window).collect()
    }

    /// Bytes of join partials currently buffered per node (the DAG
    /// join-state memory that counts against the node).
    pub fn join_state_mb(&self) -> Vec<f64> {
        self.nodes.iter().map(|n| n.join_mb).collect()
    }
}

/// Merge a completed join group (one partial per in-edge, in-edge order)
/// into the record the join operator processes: attrs merge per
/// [`ItemAttrs::merge`], payload bytes add up, lineage id is preserved.
fn merge_group(slots: Vec<Option<Item>>) -> Item {
    let mut it = slots.into_iter().flatten();
    let mut m = it.next().expect("completed join group has every slot filled");
    for p in it {
        m.attrs = m.attrs.merge(&p.attrs);
        m.size_mb += p.size_mb;
    }
    m
}
