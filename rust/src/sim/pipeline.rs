//! The streaming pipeline executor: bounded queues, continuous-batching
//! instances, cross-node transfers, OOM restarts, and backpressure — the
//! substrate everything else schedules against.
//!
//! The paper runs Ray Data on an 8-node NPU cluster; this is the simulated
//! equivalent (DESIGN.md §Hardware-Adaptation).  Dynamics modelled:
//!
//! * **bounded buffers + blocking producers** — backpressure propagates
//!   upstream; the source is throttled exactly like Ray Data's streaming
//!   executor (offline paradigm: source rate is whatever downstream admits);
//! * **continuous batching** — accelerator instances form batches up to the
//!   config-dependent effective batch; busy-time covers any in-flight work,
//!   so useful-time estimators confound occupancy with capacity;
//! * **OOM restarts** — ground-truth peak memory above device capacity
//!   kills the instance for `cold_s`, with a short conservative-batch
//!   recovery phase (vLLM-style preemption after recovery);
//! * **network egress links** — one FIFO link per node; cross-node record
//!   transfers serialize behind it, so placement decisions matter.

use std::collections::VecDeque;

use crate::config::{ClusterSpec, OperatorKind, PipelineSpec};
use crate::rngx::Rng;
use crate::sim::engine::{Engine, Ev, InstId};
use crate::sim::items::{Item, ItemAttrs};
use crate::sim::metrics::{InstWindow, InstanceMetrics, OpMetrics, OpWindowAcc};
use crate::sim::service;
use crate::workload::Trace;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstState {
    Starting,
    Running,
    /// Down for an OOM/config restart.
    Restarting,
    /// Finishing in-flight work before stopping.
    Draining,
    Stopped,
}

pub struct Instance {
    pub op: usize,
    pub node: usize,
    pub theta: Vec<f64>,
    pub state: InstState,
    pub queue: VecDeque<Item>,
    /// Outputs finished but not yet admitted downstream (blocked sender).
    pub pending_out: VecDeque<Item>,
    /// Items of the in-flight batch (empty = idle).
    pub batch: Vec<Item>,
    batch_service_s: f64,
    /// Inbound transfers reserved against our queue capacity.
    pub reserved: usize,
    /// Fanout fractional carry.
    carry: f64,
    /// Remaining batches at halved size after an OOM recovery.
    conservative: u8,
    /// Bumped on every config restart (lets tuners attribute metrics).
    pub config_gen: u32,
    /// Pending config to apply at the next idle point.
    reconfig: Option<Vec<f64>>,
    // -- window accounting --
    pub win: InstWindow,
    win_start: f64,
    down_since: Option<f64>,
    pub created_at: f64,
}

impl Instance {
    fn occupancy(&self) -> usize {
        self.queue.len() + self.reserved + self.batch.len() + self.pending_out.len()
    }

    fn has_space(&self, cap: usize) -> bool {
        self.state != InstState::Stopped
            && self.state != InstState::Draining
            && self.queue.len() + self.reserved < cap
    }

    fn idle(&self) -> bool {
        self.batch.is_empty() && self.pending_out.is_empty()
    }
}

/// Per-node mutable state.
struct NodeState {
    cpu_booked: f64,
    mem_booked: f64,
    accel_booked: u32,
    /// Egress link busy-until timestamp.
    link_free: f64,
    egress_mb_window: f64,
}

/// Waiter sentinel for the source.
const SOURCE: usize = usize::MAX;

/// The discrete-event pipeline simulator.
pub struct PipelineSim {
    pub engine: Engine,
    pub spec: PipelineSpec,
    pub cluster: ClusterSpec,
    rng: Rng,
    trace: Box<dyn Trace>,
    pub instances: Vec<Instance>,
    by_op: Vec<Vec<usize>>,
    nodes: Vec<NodeState>,
    /// Optional flow routing per edge i -> i+1: fractions[from_node][to_node].
    route: Vec<Option<Vec<Vec<f64>>>>,
    /// Instances (or SOURCE) blocked on space in each operator's queues.
    waiters: Vec<Vec<usize>>,
    op_acc: Vec<OpWindowAcc>,
    /// Lifetime EMA of processed item attrs per op (capacity-oracle input).
    attr_ema: Vec<Option<ItemAttrs>>,
    /// Amplification factors D_i and D_o.
    pub d_i: Vec<f64>,
    pub d_o: f64,
    pub items_emitted: u64,
    pub out_records: u64,
    out_window: u64,
    win_start: f64,
    /// Cumulative OOM downtime per op, seconds (Table 6).
    pub oom_downtime_s: Vec<f64>,
    pub oom_events_total: Vec<u32>,
    /// Network transfer latency floor, s.
    net_latency: f64,
    source_done: bool,
    /// Previous window's queue-end per op (queue-trend signal).
    prev_q_end: Vec<usize>,
}

impl PipelineSim {
    pub fn new(
        spec: PipelineSpec,
        cluster: ClusterSpec,
        trace: Box<dyn Trace>,
        seed: u64,
    ) -> Self {
        let n_ops = spec.n_ops();
        let (d_i, d_o) = spec.amplification();
        let nodes = cluster
            .nodes
            .iter()
            .map(|_| NodeState {
                cpu_booked: 0.0,
                mem_booked: 0.0,
                accel_booked: 0,
                link_free: 0.0,
                egress_mb_window: 0.0,
            })
            .collect();
        let mut engine = Engine::new();
        engine.at(0.0, Ev::SourceEmit);
        PipelineSim {
            engine,
            rng: Rng::new(seed),
            trace,
            instances: Vec::new(),
            by_op: vec![Vec::new(); n_ops],
            nodes,
            route: vec![None; n_ops.saturating_sub(1)],
            waiters: vec![Vec::new(); n_ops],
            op_acc: vec![OpWindowAcc::new(); n_ops],
            attr_ema: vec![None; n_ops],
            d_i,
            d_o,
            items_emitted: 0,
            out_records: 0,
            out_window: 0,
            win_start: 0.0,
            oom_downtime_s: vec![0.0; n_ops],
            oom_events_total: vec![0; n_ops],
            net_latency: 1e-3,
            source_done: false,
            prev_q_end: vec![0; n_ops],
            spec,
            cluster,
        }
    }

    pub fn now(&self) -> f64 {
        self.engine.now()
    }

    pub fn instances_of(&self, op: usize) -> Vec<usize> {
        self.by_op[op]
            .iter()
            .copied()
            .filter(|&i| self.instances[i].state != InstState::Stopped)
            .collect()
    }

    /// Live (non-draining) instance count per (op, node).
    pub fn placement(&self) -> Vec<Vec<u32>> {
        let mut x = vec![vec![0u32; self.cluster.nodes.len()]; self.spec.n_ops()];
        for inst in &self.instances {
            if matches!(inst.state, InstState::Stopped | InstState::Draining) {
                continue;
            }
            x[inst.op][inst.node] += 1;
        }
        x
    }

    /// Set flow routing for edge `op -> op+1`.
    pub fn set_route(&mut self, op: usize, fractions: Option<Vec<Vec<f64>>>) {
        self.route[op] = fractions;
    }

    // ------------------------------------------------------------------
    // Instance lifecycle
    // ------------------------------------------------------------------

    /// Launch an instance of `op` on `node` with config θ.  Fails if the
    /// node lacks accelerator capacity.
    pub fn add_instance(&mut self, op: usize, node: usize, theta: Vec<f64>) -> Result<usize, String> {
        let o = &self.spec.operators[op];
        let ns = &mut self.nodes[node];
        let nspec = &self.cluster.nodes[node];
        if o.accels > 0 && ns.accel_booked + o.accels > nspec.accels {
            return Err(format!(
                "node {node} out of accelerators for {} ({}+{} > {})",
                o.name, ns.accel_booked, o.accels, nspec.accels
            ));
        }
        ns.cpu_booked += o.cpu;
        ns.mem_booked += o.mem_gb;
        ns.accel_booked += o.accels;
        let now = self.engine.now();
        let id = self.instances.len();
        self.instances.push(Instance {
            op,
            node,
            theta,
            state: InstState::Starting,
            queue: VecDeque::new(),
            pending_out: VecDeque::new(),
            batch: Vec::new(),
            batch_service_s: 0.0,
            reserved: 0,
            carry: 0.0,
            conservative: 0,
            config_gen: 0,
            reconfig: None,
            win: InstWindow::default(),
            win_start: now,
            down_since: Some(now),
            created_at: now,
        });
        self.by_op[op].push(id);
        self.engine.after(o.start_s, Ev::InstanceReady(InstId(id)));
        Ok(id)
    }

    /// Gracefully stop an instance (drains in-flight work first).
    pub fn stop_instance(&mut self, id: usize) {
        let inst = &mut self.instances[id];
        if inst.state == InstState::Stopped {
            return;
        }
        if inst.idle() {
            // Covers Running-idle, Starting, and Restarting (no in-flight
            // batch to drain in any of those states).
            self.finalize_stop(id);
        } else {
            inst.state = InstState::Draining;
        }
    }

    /// Restart an instance with a new configuration (rolling update step).
    /// Applied at the next idle point; incurs `cold_s` downtime.
    pub fn restart_with_config(&mut self, id: usize, theta: Vec<f64>) {
        let inst = &mut self.instances[id];
        if inst.state == InstState::Stopped {
            return;
        }
        inst.reconfig = Some(theta);
        if inst.batch.is_empty() {
            self.apply_reconfig(id);
        }
    }

    fn apply_reconfig(&mut self, id: usize) {
        let now = self.engine.now();
        let cold = self.spec.operators[self.instances[id].op].cold_s;
        let inst = &mut self.instances[id];
        if let Some(theta) = inst.reconfig.take() {
            inst.theta = theta;
            inst.config_gen += 1;
            inst.state = InstState::Restarting;
            if inst.down_since.is_none() {
                inst.down_since = Some(now);
            }
            self.engine.after(cold, Ev::InstanceReady(InstId(id)));
        }
    }

    fn finalize_stop(&mut self, id: usize) {
        let (op, node) = (self.instances[id].op, self.instances[id].node);
        // Account trailing downtime.
        let now = self.engine.now();
        {
            let inst = &mut self.instances[id];
            if let Some(d) = inst.down_since.take() {
                inst.win.down_s += now - d.max(inst.win_start);
            }
            inst.state = InstState::Stopped;
        }
        let o = &self.spec.operators[op];
        let ns = &mut self.nodes[node];
        ns.cpu_booked -= o.cpu;
        ns.mem_booked -= o.mem_gb;
        ns.accel_booked -= o.accels;
        // Redistribute any leftover queue items to peers.
        let leftovers: Vec<Item> = self.instances[id].queue.drain(..).collect();
        let peers = self.instances_of(op);
        if !peers.is_empty() {
            for (i, item) in leftovers.into_iter().enumerate() {
                let dest = peers[i % peers.len()];
                self.instances[dest].queue.push_back(item);
            }
            for p in peers {
                self.try_start(p);
            }
        }
        self.wake_waiters(op);
    }

    // ------------------------------------------------------------------
    // Event handling
    // ------------------------------------------------------------------

    /// Run the simulation until `t_end` (absolute seconds).
    pub fn run_until(&mut self, t_end: f64) {
        while let Some(ev) = self.engine.next_before(t_end) {
            match ev {
                Ev::SourceEmit => self.try_source(),
                Ev::InstanceReady(InstId(id)) => self.on_ready(id),
                Ev::BatchDone(InstId(id)) => self.on_batch_done(id),
                Ev::TransferDone(InstId(id), item) => self.on_transfer(id, item),
            }
        }
        self.engine.advance_to(t_end);
    }

    fn on_ready(&mut self, id: usize) {
        let now = self.engine.now();
        let inst = &mut self.instances[id];
        match inst.state {
            InstState::Starting | InstState::Restarting => {
                if let Some(d) = inst.down_since.take() {
                    inst.win.down_s += now - d.max(inst.win_start);
                }
                if inst.state == InstState::Restarting {
                    // leave conservative counter as set by the OOM path
                } else {
                    inst.conservative = 0;
                }
                inst.state = InstState::Running;
                self.try_start(id);
                // A fresh instance frees queue space semantics upstream.
                let op = self.instances[id].op;
                self.wake_waiters(op);
            }
            _ => {}
        }
    }

    fn on_transfer(&mut self, id: usize, item: Item) {
        let inst = &mut self.instances[id];
        inst.reserved = inst.reserved.saturating_sub(1);
        if inst.state == InstState::Stopped {
            // Late arrival to a stopped instance: reroute.
            let op = inst.op;
            self.deliver_local_or_requeue(op, item);
            return;
        }
        inst.queue.push_back(item);
        self.try_start(id);
    }

    fn deliver_local_or_requeue(&mut self, op: usize, item: Item) {
        let peers = self.instances_of(op);
        if let Some(&dest) = peers.iter().min_by_key(|&&p| self.instances[p].occupancy()) {
            self.instances[dest].queue.push_back(item);
            self.try_start(dest);
        }
        // else: dropped (no live instance — cannot happen under MILP plans
        // which keep p_i >= 1).
    }

    fn try_source(&mut self) {
        if self.source_done {
            return;
        }
        let cap = self.spec.operators[0].queue_cap;
        loop {
            // Find an op-0 instance with space.
            let dest = self.by_op[0]
                .iter()
                .copied()
                .filter(|&i| self.instances[i].has_space(cap))
                .min_by_key(|&i| self.instances[i].occupancy());
            let Some(dest) = dest else {
                if !self.waiters[0].contains(&SOURCE) {
                    self.waiters[0].push(SOURCE);
                }
                return;
            };
            match self.trace.next_item(&mut self.rng) {
                Some(item) => {
                    self.items_emitted += 1;
                    self.instances[dest].queue.push_back(item);
                    self.try_start(dest);
                }
                None => {
                    self.source_done = true;
                    return;
                }
            }
        }
    }

    /// Try to begin a batch on `id`.
    fn try_start(&mut self, id: usize) {
        let cap_mem_mb = {
            let inst = &self.instances[id];
            self.cluster.nodes[inst.node].accel_mem_mb
        };
        let now = self.engine.now();
        let inst = &self.instances[id];
        if inst.state != InstState::Running {
            return;
        }
        if !inst.batch.is_empty() || !inst.pending_out.is_empty() || inst.queue.is_empty() {
            return;
        }
        let op_idx = inst.op;
        let op = &self.spec.operators[op_idx];

        // Sample queue length for backlog signals.
        let qlen = inst.queue.len();

        // Form the batch.  A post-OOM recovery phase runs with a halved
        // config (vLLM-style preemption/recompute after an OOM abort).
        let theta_eff: Vec<f64> = if inst.conservative > 0 {
            let mut t = inst.theta.clone();
            if !t.is_empty() {
                t[0] = (t[0] / 2.0).max(1.0);
            }
            if t.len() > 1 {
                t[1] = (t[1] / 2.0).max(256.0);
            }
            t
        } else {
            inst.theta.clone()
        };
        let batch_n = match op.kind {
            OperatorKind::CpuSync => 1,
            OperatorKind::AccelAsync => {
                service::accel_eff_batch(&theta_eff).min(inst.queue.len()).max(1)
            }
        };

        let inst = &mut self.instances[id];
        inst.win.q_sum += qlen as f64;
        inst.win.q_n += 1;
        let items: Vec<Item> = inst.queue.drain(..batch_n).collect();
        if inst.conservative > 0 {
            inst.conservative -= 1;
        }

        // Service time + memory check.
        let (service_s, oom) = match op.kind {
            OperatorKind::CpuSync => {
                let contention = {
                    let node = &self.nodes[inst.node];
                    let cores = self.cluster.nodes[inst.node].cpu_cores;
                    (cores / node.cpu_booked.max(1e-9)).min(1.0)
                };
                let t = service::cpu_record_time(&op.service, &items[0].attrs, &mut self.rng)
                    / contention;
                (t, false)
            }
            OperatorKind::AccelAsync => {
                let stats = service::BatchStats::of(
                    &items.iter().map(|i| i.attrs).collect::<Vec<_>>(),
                );
                let mem = service::accel_batch_mem(&op.service, &theta_eff, stats, &mut self.rng);
                let inst = &mut self.instances[id];
                inst.win.peak_mem_mb = inst.win.peak_mem_mb.max(mem);
                if mem > cap_mem_mb {
                    (0.0, true)
                } else {
                    (
                        service::accel_batch_time(&op.service, &theta_eff, stats, &mut self.rng),
                        false,
                    )
                }
            }
        };

        let cold = op.cold_s;
        let inst = &mut self.instances[id];
        if oom {
            // OOM: items return to the queue; instance restarts cold.
            for item in items.into_iter().rev() {
                inst.queue.push_front(item);
            }
            inst.win.oom_events += 1;
            inst.state = InstState::Restarting;
            inst.down_since = Some(now);
            inst.conservative = 4;
            self.oom_events_total[op_idx] += 1;
            self.oom_downtime_s[op_idx] += cold;
            self.engine.after(cold, Ev::InstanceReady(InstId(id)));
            return;
        }
        inst.batch = items;
        inst.batch_service_s = service_s;
        self.engine.after(service_s, Ev::BatchDone(InstId(id)));
    }

    fn on_batch_done(&mut self, id: usize) {
        let op_idx = self.instances[id].op;
        let op = self.spec.operators[op_idx].clone();
        let is_last = op_idx + 1 == self.spec.n_ops();

        // Account the batch.
        let items: Vec<Item> = {
            let inst = &mut self.instances[id];
            let items = std::mem::take(&mut inst.batch);
            inst.win.records_done += items.len() as u64;
            inst.win.batches_done += 1;
            inst.win.busy_s += inst.batch_service_s;
            items
        };
        self.op_acc[op_idx].records_in += items.len() as u64;
        for item in &items {
            let mut r = self.rng.fork(7);
            self.op_acc[op_idx].observe(item, op.features, &mut r);
            // Lifetime attr EMA (capacity-oracle input).
            let ema = &mut self.attr_ema[op_idx];
            let a = item.attrs;
            *ema = Some(match ema {
                None => a,
                Some(e) => ItemAttrs {
                    tokens_in: e.tokens_in * 0.99 + a.tokens_in * 0.01,
                    tokens_out: e.tokens_out * 0.99 + a.tokens_out * 0.01,
                    pixels_m: e.pixels_m * 0.99 + a.pixels_m * 0.01,
                    frames: e.frames * 0.99 + a.frames * 0.01,
                },
            });
        }

        // Fanout into children.
        let mut outputs: Vec<Item> = Vec::new();
        {
            let inst = &mut self.instances[id];
            for item in &items {
                inst.carry += op.fanout;
                let k = inst.carry.floor() as usize;
                inst.carry -= k as f64;
                for _ in 0..k {
                    let a = item.attrs;
                    let s = op.child_scale;
                    outputs.push(Item {
                        attrs: ItemAttrs {
                            tokens_in: a.tokens_in * s[0],
                            tokens_out: a.tokens_out * s[1],
                            pixels_m: a.pixels_m * s[2],
                            frames: a.frames * s[3],
                        },
                        size_mb: op.out_mb * self.rng.lognormal(0.0, 0.15),
                        regime: item.regime,
                    });
                }
            }
        }

        if is_last {
            self.out_records += outputs.len() as u64;
            self.out_window += outputs.len() as u64;
        } else {
            let inst = &mut self.instances[id];
            inst.pending_out.extend(outputs);
        }

        // Space freed in our queue: wake upstream.
        self.wake_waiters(op_idx);

        // Apply a pending reconfig at this idle point.
        if self.instances[id].reconfig.is_some() && self.instances[id].pending_out.is_empty() {
            self.apply_reconfig(id);
            return;
        }

        self.try_place_outputs(id);
        let inst = &self.instances[id];
        if inst.state == InstState::Draining && inst.idle() {
            // In-flight work done and outputs placed: release (leftover
            // queue items are redistributed by finalize_stop).
            self.finalize_stop(id);
            return;
        }
        self.try_start(id);
    }

    /// Push pending outputs downstream; block on full queues.
    fn try_place_outputs(&mut self, id: usize) {
        let op = self.instances[id].op;
        if op + 1 >= self.spec.n_ops() {
            return;
        }
        let next = op + 1;
        let cap = self.spec.operators[next].queue_cap;
        loop {
            let Some(&item) = self.instances[id].pending_out.front() else {
                break;
            };
            let from_node = self.instances[id].node;
            let Some(dest) = self.choose_dest(op, from_node, cap) else {
                if !self.waiters[next].contains(&id) {
                    self.waiters[next].push(id);
                }
                return;
            };
            self.instances[id].pending_out.pop_front();
            let dest_node = self.instances[dest].node;
            if dest_node == from_node {
                self.instances[dest].queue.push_back(item);
                self.try_start(dest);
            } else {
                // Cross-node: serialize behind the egress link.
                let now = self.engine.now();
                let rate = self.cluster.nodes[from_node].egress_mbps.max(1.0);
                let ns = &mut self.nodes[from_node];
                ns.egress_mb_window += item.size_mb;
                let start = ns.link_free.max(now);
                let arrive = start + item.size_mb / rate + self.net_latency;
                ns.link_free = arrive;
                self.instances[dest].reserved += 1;
                self.engine.at(arrive, Ev::TransferDone(InstId(dest), item));
            }
        }
        // Fully drained: if a reconfig is pending and we're idle, apply it.
        if self.instances[id].batch.is_empty() && self.instances[id].reconfig.is_some() {
            self.apply_reconfig(id);
        }
    }

    /// Pick a destination instance for edge `op -> op+1` from `from_node`,
    /// honouring the flow plan when present.
    fn choose_dest(&mut self, op: usize, from_node: usize, cap: usize) -> Option<usize> {
        let next = op + 1;
        if let Some(w) = &self.route[op] {
            let weights = &w[from_node];
            if weights.iter().sum::<f64>() > 1e-9 {
                let l = self.rng.categorical(weights);
                // Least-occupied instance with space on the sampled node.
                let best = self.by_op[next]
                    .iter()
                    .copied()
                    .filter(|&i| self.instances[i].node == l && self.instances[i].has_space(cap))
                    .min_by_key(|&i| self.instances[i].occupancy());
                if best.is_some() {
                    return best;
                }
            }
        }
        // Fallback / no plan: least-occupied anywhere (local first on tie).
        self.by_op[next]
            .iter()
            .copied()
            .filter(|&i| self.instances[i].has_space(cap))
            .min_by_key(|&i| {
                (self.instances[i].occupancy(), (self.instances[i].node != from_node) as usize)
            })
    }

    fn wake_waiters(&mut self, op: usize) {
        let ws = std::mem::take(&mut self.waiters[op]);
        for w in ws {
            if w == SOURCE {
                self.try_source();
            } else {
                self.try_place_outputs(w);
                if self.instances[w].state == InstState::Draining && self.instances[w].idle() {
                    self.finalize_stop(w);
                } else {
                    self.try_start(w);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Metrics & oracles
    // ------------------------------------------------------------------

    /// Flush the metrics window: per-operator snapshots + pipeline output
    /// records this window.  Resets window accumulators.
    pub fn flush_metrics(&mut self) -> (Vec<OpMetrics>, u64) {
        let now = self.engine.now();
        let window_s = (now - self.win_start).max(1e-9);
        let mut out = Vec::with_capacity(self.spec.n_ops());
        for op in 0..self.spec.n_ops() {
            let mut records = 0u64;
            let mut busy = 0.0;
            let mut active = 0.0;
            let mut peak_mem: f64 = 0.0;
            let mut ooms = 0u32;
            let mut q_end = 0usize;
            let mut q_sum = 0.0;
            let mut q_n = 0u64;
            let mut n_active = 0usize;
            let mut per_instance = Vec::new();
            for &i in &self.by_op[op] {
                let inst = &mut self.instances[i];
                if inst.state == InstState::Stopped {
                    continue;
                }
                let start = inst.win_start.max(inst.created_at);
                let mut down = inst.win.down_s;
                if let Some(d) = inst.down_since {
                    down += now - d.max(start);
                }
                let a = (now - start - down).max(0.0);
                records += inst.win.records_done;
                busy += inst.win.busy_s;
                active += a;
                peak_mem = peak_mem.max(inst.win.peak_mem_mb);
                ooms += inst.win.oom_events;
                q_end += inst.queue.len();
                q_sum += inst.win.q_sum;
                q_n += inst.win.q_n;
                if a > 0.0 {
                    n_active += 1;
                }
                per_instance.push(InstanceMetrics {
                    inst: i,
                    node: inst.node,
                    records: inst.win.records_done,
                    busy_s: inst.win.busy_s,
                    active_s: a,
                    peak_mem_mb: inst.win.peak_mem_mb,
                    oom_events: inst.win.oom_events,
                    queue_len: inst.queue.len(),
                    config_gen: inst.config_gen,
                });
                inst.win.reset();
                inst.win_start = now;
            }
            let acc = &mut self.op_acc[op];
            let (feat_mean, feat_std) = acc.mean_std();
            let q_begin = self
                .prev_q_end
                .get(op)
                .copied()
                .unwrap_or(0);
            out.push(OpMetrics {
                op,
                window_s,
                records_in: acc.records_in,
                records_out: records,
                rate_per_inst: if active > 0.0 { records as f64 / (active / n_active.max(1) as f64) / n_active.max(1) as f64 } else { 0.0 },
                utilization: if active > 0.0 { (busy / active).min(1.0) } else { 0.0 },
                queue_begin: q_begin,
                queue_end: q_end,
                queue_avg: if q_n > 0 { q_sum / q_n as f64 } else { q_end as f64 },
                feat_mean,
                feat_std,
                peak_mem_mb: peak_mem,
                oom_events: ooms,
                n_active,
                cluster_samples: std::mem::take(&mut acc.reservoir),
                per_instance,
            });
            acc.reset();
        }
        // Record queue-end as next window's queue-begin.
        self.prev_q_end = out.iter().map(|m| m.queue_end).collect();
        for ns in &mut self.nodes {
            ns.egress_mb_window = 0.0;
        }
        let w = self.out_window;
        self.out_window = 0;
        self.win_start = now;
        (out, w)
    }

    /// Ground-truth sustainable per-instance rate for `op` under config θ
    /// and the currently observed workload (isolated-profiling oracle —
    /// evaluation only, never fed to the scheduler).
    pub fn true_unit_rate(&self, op: usize, theta: &[f64]) -> f64 {
        let attrs = self.attr_ema[op].unwrap_or(ItemAttrs {
            tokens_in: 512.0,
            tokens_out: 64.0,
            pixels_m: 1.0,
            frames: 1.0,
        });
        service::true_unit_rate(&self.spec.operators[op].service, theta, &attrs)
    }

    /// Current mean attrs seen by `op` (oracle input for benches).
    pub fn mean_attrs(&self, op: usize) -> Option<ItemAttrs> {
        self.attr_ema[op]
    }

    /// Pipeline throughput in original-input records/s over the whole run.
    pub fn avg_throughput(&self) -> f64 {
        if self.now() <= 0.0 {
            return 0.0;
        }
        (self.out_records as f64 / self.d_o) / self.now()
    }

    /// True when the trace is exhausted and no work remains in flight.
    pub fn drained(&self) -> bool {
        self.source_done
            && self
                .instances
                .iter()
                .all(|i| i.state == InstState::Stopped || (i.idle() && i.queue.is_empty()))
    }

    /// Egress MB sent by each node in the current window (network metric).
    pub fn egress_window_mb(&self) -> Vec<f64> {
        self.nodes.iter().map(|n| n.egress_mb_window).collect()
    }
}
