//! In-flight cross-node record movement: a slab of transfer payloads keyed
//! by POD slot ids, plus per-link FIFO queues.
//!
//! A *link* here is whatever unit serializes transfers so that the arrival
//! times queued behind it are strictly increasing.  The pipeline keys links
//! per `(node, tenant)`: each tenant owns a fixed WFQ share of its node's
//! egress (see `PipelineSim::egress_share`), so one tenant's sub-link
//! serializes its own records while tenants proceed independently — which
//! is also what keeps the invariant intact when tenants are sharded across
//! worker threads.  Each link's queue is already sorted by `(arrive, seq)`
//! and a plain `VecDeque` holds a whole backlog ("batch") with no
//! per-record heap traffic.  A small index min-heap over the current link
//! *heads* locates the globally next arrival in `O(log links)`; the
//! pipeline merges that key with the event heap's root at pop time, so
//! deliveries happen at exactly the per-item instants and order the legacy
//! one-event-per-record stream produced.
//!
//! Every entry carries its own `(arrive, seq)` key (seq from the engine's
//! single counter — see [`Engine::alloc_seq`](crate::sim::Engine::alloc_seq)),
//! which is what makes batched storage *bit-identical* to the seed event
//! stream rather than merely approximately equivalent.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::sim::items::Item;

/// One in-flight transfer: arrival key + destination ids + payload slot.
#[derive(Debug, Clone, Copy)]
pub struct LinkEntry {
    /// Arrival time at the destination (absolute seconds).
    pub t: f64,
    /// Tie-break sequence number from the engine's global counter.
    pub seq: u64,
    /// Destination instance (dense id).
    pub dest: u32,
    /// Pipeline edge the record travels on.
    pub edge: u32,
    /// Payload slot in the transfer slab.
    pub slot: u32,
}

/// Slab of in-flight transfer payloads + per-link FIFOs.
pub struct TransferNet {
    /// Payload slab; freed slots are recycled via `free`.
    slab: Vec<Item>,
    free: Vec<u32>,
    in_flight: usize,
    peak_in_flight: usize,
    /// Per-link FIFO of transfers serialized behind that link (batched
    /// mode only; the seed event stream bypasses these).  An unused link
    /// is an empty `VecDeque` — no allocation.
    links: Vec<VecDeque<LinkEntry>>,
    /// Min-heap over current link heads, keyed `(t.to_bits(), seq, link)`.
    /// Arrival times are finite and non-negative, so the IEEE-754 bit
    /// pattern orders exactly like the float.  Each transfer is pushed
    /// here exactly once — when it reaches the head of its link's FIFO —
    /// so entries are never stale and no lazy deletion is needed.
    heads: BinaryHeap<Reverse<(u64, u64, u32)>>,
    queued: usize,
}

impl TransferNet {
    pub fn new(n_links: usize) -> Self {
        TransferNet {
            slab: Vec::new(),
            free: Vec::new(),
            in_flight: 0,
            peak_in_flight: 0,
            links: vec![VecDeque::new(); n_links],
            heads: BinaryHeap::new(),
            queued: 0,
        }
    }

    /// Park a record in the slab; returns its slot id (recycled slots
    /// first, so the slab's footprint tracks the in-flight high-water).
    pub fn put_item(&mut self, item: Item) -> u32 {
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = item;
                s
            }
            None => {
                debug_assert!(self.slab.len() < u32::MAX as usize, "transfer slab overflows u32");
                self.slab.push(item);
                (self.slab.len() - 1) as u32
            }
        };
        self.in_flight += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        slot
    }

    /// Take a record out of the slab, freeing its slot.
    pub fn take_item(&mut self, slot: u32) -> Item {
        let item = self.slab[slot as usize];
        self.free.push(slot);
        self.in_flight -= 1;
        item
    }

    /// Append a transfer to `link`'s FIFO.  Arrival times behind one
    /// link are strictly increasing (the link serializes), so the deque
    /// stays sorted by construction.
    pub fn enqueue(&mut self, link: usize, e: LinkEntry) {
        debug_assert!(e.t.is_finite() && e.t >= 0.0, "arrival keys must bit-order");
        debug_assert!(
            self.links[link].back().map(|b| (b.t, b.seq) < (e.t, e.seq)).unwrap_or(true),
            "link FIFO keys must be strictly increasing"
        );
        self.links[link].push_back(e);
        self.queued += 1;
        if self.links[link].len() == 1 {
            self.heads.push(Reverse((e.t.to_bits(), e.seq, link as u32)));
        }
    }

    /// The earliest pending `(arrive, seq)` key across all links, if any.
    #[inline]
    pub fn peek_min(&self) -> Option<(f64, u64)> {
        self.heads.peek().map(|Reverse((tb, seq, _))| (f64::from_bits(*tb), *seq))
    }

    /// Pop the globally earliest transfer (caller guarantees non-empty)
    /// and promote its link's next entry to the heads heap.
    pub fn pop_min(&mut self) -> LinkEntry {
        let Reverse((_, _, link)) = self.heads.pop().expect("pop_min on empty TransferNet");
        let q = &mut self.links[link as usize];
        let e = q.pop_front().expect("heads entry tracks a non-empty link");
        self.queued -= 1;
        if let Some(head) = q.front() {
            self.heads.push(Reverse((head.t.to_bits(), head.seq, link)));
        }
        e
    }

    /// No transfers queued behind any link (slab occupancy may still be
    /// non-zero in seed-event-stream mode, where payloads are slab-stored
    /// but scheduled through the event heap).
    pub fn is_idle(&self) -> bool {
        self.queued == 0
    }

    /// Transfers currently in the slab (both modes).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// High-water mark of simultaneous in-flight transfers.
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::items::ItemAttrs;

    fn item(id: u64, mb: f64) -> Item {
        Item {
            id,
            attrs: ItemAttrs { tokens_in: 1.0, tokens_out: 1.0, pixels_m: 1.0, frames: 1.0 },
            size_mb: mb,
            regime: 0,
        }
    }

    #[test]
    fn slab_recycles_slots_and_tracks_peak() {
        let mut net = TransferNet::new(2);
        let a = net.put_item(item(1, 0.5));
        let b = net.put_item(item(2, 0.7));
        assert_ne!(a, b);
        assert_eq!(net.peak_in_flight(), 2);
        assert_eq!(net.take_item(a).id, 1);
        let c = net.put_item(item(3, 0.9));
        assert_eq!(c, a, "freed slot is recycled");
        assert_eq!(net.take_item(b).id, 2);
        assert_eq!(net.take_item(c).id, 3);
        assert_eq!(net.in_flight(), 0);
        assert_eq!(net.peak_in_flight(), 2);
    }

    #[test]
    fn pop_min_merges_links_by_time_then_seq() {
        let mut net = TransferNet::new(3);
        // Link 0 and link 2 interleave in time; equal times break by seq.
        let mk = |t, seq, slot| LinkEntry { t, seq, dest: 0, edge: 0, slot };
        net.enqueue(0, mk(1.0, 1, 10));
        net.enqueue(0, mk(3.0, 5, 11));
        net.enqueue(2, mk(1.0, 2, 20));
        net.enqueue(2, mk(2.0, 3, 21));
        let order: Vec<u32> = (0..4).map(|_| net.pop_min().slot).collect();
        assert_eq!(order, vec![10, 20, 21, 11]);
        assert!(net.is_idle());
        assert!(net.peek_min().is_none());
    }
}
