//! Workload trace generators: synthetic equivalents of the paper's PDF
//! (~200k documents, three types processed sequentially) and video
//! (~410k clips, two categories) corpora, plus the speech curation DAG
//! (fork/join modality-parallel branches, three regimes).
//!
//! The regime *structure* — sequential type switches with distinct feature
//! distributions — is what the observation/adaptation layers react to; item
//! contents are irrelevant (DESIGN.md §Hardware-Adaptation).

pub mod pdf;
pub mod speech;
pub mod video;

use crate::rngx::Rng;
use crate::sim::items::Item;

/// A source of input items.  `None` ends the trace.
///
/// `Send` so a `PipelineSim` (which boxes its traces) can move into a
/// scoped worker thread of the sharded facade; every trace is plain data.
pub trait Trace: Send {
    fn next_item(&mut self, rng: &mut Rng) -> Option<Item>;
    /// Number of distinct ground-truth regimes (clustering evaluation).
    fn n_regimes(&self) -> usize;
}

/// A regime phase: `count` items drawn from one distribution.
#[derive(Debug, Clone)]
pub struct Phase {
    pub regime: u8,
    pub count: u64,
    pub sampler: ItemDist,
}

/// Parametric item distribution (lognormal token/pixel loads).
#[derive(Debug, Clone, Copy)]
pub struct ItemDist {
    /// lognormal (mu, sigma) of prefill tokens
    pub tokens_in: (f64, f64),
    /// lognormal (mu, sigma) of decode tokens
    pub tokens_out: (f64, f64),
    /// lognormal (mu, sigma) of megapixels
    pub pixels_m: (f64, f64),
    /// lognormal (mu, sigma) of frames
    pub frames: (f64, f64),
    /// input record size, MB (lognormal)
    pub size_mb: (f64, f64),
}

impl ItemDist {
    pub fn sample(&self, regime: u8, rng: &mut Rng) -> Item {
        let ln = |rng: &mut Rng, (mu, sigma): (f64, f64)| rng.lognormal(mu, sigma);
        Item {
            // The simulator assigns lineage ids when the source emits.
            id: 0,
            attrs: crate::sim::items::ItemAttrs {
                tokens_in: ln(rng, self.tokens_in),
                tokens_out: ln(rng, self.tokens_out),
                pixels_m: ln(rng, self.pixels_m),
                frames: ln(rng, self.frames),
            },
            size_mb: ln(rng, self.size_mb),
            regime,
        }
    }

    /// Mean of the lognormal tokens_in (analytics/tests).
    pub fn mean_tokens_in(&self) -> f64 {
        (self.tokens_in.0 + 0.5 * self.tokens_in.1 * self.tokens_in.1).exp()
    }
}

/// Sequential-phase trace (the paper processes dataset segments by type).
pub struct PhasedTrace {
    phases: Vec<Phase>,
    idx: usize,
    emitted_in_phase: u64,
    n_regimes: usize,
}

impl PhasedTrace {
    pub fn new(phases: Vec<Phase>) -> Self {
        let n_regimes = phases
            .iter()
            .map(|p| p.regime as usize + 1)
            .max()
            .unwrap_or(0);
        PhasedTrace { phases, idx: 0, emitted_in_phase: 0, n_regimes }
    }

    /// Total items across phases.
    pub fn total(&self) -> u64 {
        self.phases.iter().map(|p| p.count).sum()
    }
}

impl Trace for PhasedTrace {
    fn next_item(&mut self, rng: &mut Rng) -> Option<Item> {
        while self.idx < self.phases.len() {
            let ph = &self.phases[self.idx];
            if self.emitted_in_phase < ph.count {
                self.emitted_in_phase += 1;
                return Some(ph.sampler.sample(ph.regime, rng));
            }
            self.idx += 1;
            self.emitted_in_phase = 0;
        }
        None
    }

    fn n_regimes(&self) -> usize {
        self.n_regimes
    }
}

/// Endless single-regime trace (isolated-operator benches).
pub struct UniformTrace {
    pub dist: ItemDist,
    pub regime: u8,
}

impl Trace for UniformTrace {
    fn next_item(&mut self, rng: &mut Rng) -> Option<Item> {
        Some(self.dist.sample(self.regime, rng))
    }

    fn n_regimes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(mu: f64) -> ItemDist {
        ItemDist {
            tokens_in: (mu, 0.3),
            tokens_out: (4.0, 0.3),
            pixels_m: (0.0, 0.1),
            frames: (0.0, 0.0),
            size_mb: (0.0, 0.1),
        }
    }

    #[test]
    fn phased_trace_switches_and_ends() {
        let mut t = PhasedTrace::new(vec![
            Phase { regime: 0, count: 10, sampler: dist(5.0) },
            Phase { regime: 1, count: 5, sampler: dist(8.0) },
        ]);
        let mut rng = Rng::new(0);
        let mut regimes = Vec::new();
        while let Some(item) = t.next_item(&mut rng) {
            regimes.push(item.regime);
        }
        assert_eq!(regimes.len(), 15);
        assert_eq!(&regimes[..10], &[0; 10]);
        assert_eq!(&regimes[10..], &[1; 5]);
        assert_eq!(t.n_regimes(), 2);
    }

    #[test]
    fn regimes_statistically_distinct() {
        let mut rng = Rng::new(1);
        let d0 = dist(5.0);
        let d1 = dist(8.0);
        let m0: f64 =
            (0..500).map(|_| d0.sample(0, &mut rng).attrs.tokens_in).sum::<f64>() / 500.0;
        let m1: f64 =
            (0..500).map(|_| d1.sample(1, &mut rng).attrs.tokens_in).sum::<f64>() / 500.0;
        assert!(m1 > 5.0 * m0, "regimes must differ strongly: {m0} vs {m1}");
    }
}
