//! Video curation pipeline + clip trace (paper §8.1): 9 operators across
//! four stages — scene-based splitting, aesthetic filtering (CLIP, NPU),
//! OCR-based text filtering (CRAFT, NPU), and LLM captioning
//! (Qwen2.5-VL-7B, NPU).  Trace: short-form clips then long-form videos.

use crate::config::{
    ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec, ServiceModel,
};
use crate::sim::ItemAttrs;
use crate::workload::{ItemDist, Phase, PhasedTrace};

/// Nominal source-item attrs (first-regime means) used by the CLI,
/// benches, and tests — the single definition point.
pub fn src_attrs() -> ItemAttrs {
    ItemAttrs { tokens_in: 5_400.0, tokens_out: 480.0, pixels_m: 0.9, frames: 600.0 }
}

fn cpu_op(
    name: &str,
    cpu: f64,
    mem_gb: f64,
    base_rate: f64,
    cost: CostW,
    ref_cost: f64,
    fanout: f64,
    out_mb: f64,
    child_scale: [f64; 4],
) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::CpuSync,
        cpu,
        mem_gb,
        accels: 0,
        fanout,
        out_mb,
        start_s: 2.0,
        stop_s: 1.0,
        cold_s: 4.0,
        tunable: false,
        config_space: ConfigSpace::default(),
        service: ServiceModel::Cpu { base_rate, ref_cost, cost },
        features: FeatureExtractor::Cost,
        child_scale,
        queue_cap: 192,
    }
}

fn vision_op(
    name: &str,
    peak_tok_rate: f64,
    fanout: f64,
    out_mb: f64,
    mem_base_mb: f64,
) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::AccelAsync,
        cpu: 4.0,
        mem_gb: 16.0,
        accels: 1,
        fanout,
        out_mb,
        start_s: 5.0,
        stop_s: 2.0,
        cold_s: 12.0,
        tunable: true,
        config_space: ConfigSpace::llm_engine(),
        service: ServiceModel::Accel {
            peak_tok_rate,
            batch_half: 10.0,
            decode_weight: 1.0,
            prefix_share: 0.05,
            mem_base_mb,
            kv_mb_per_token: 0.012,
            act_mb_per_token: 1.1,
            mem_noise_sigma: 0.025,
        },
        features: FeatureExtractor::Vision,
        child_scale: [1.0; 4],
        queue_cap: 384,
    }
}

/// The 9-operator video curation pipeline.
pub fn pipeline() -> PipelineSpec {
    let no_scale = [1.0; 4];
    let ops = vec![
        // --- stage 1: scene-based splitting --------------------------------
        cpu_op("probe", 0.5, 1.0, 18.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.5, no_scale),
        // decode cost scales with frames x resolution; emits raw frame groups
        cpu_op("decode", 4.0, 8.0, 5.0, CostW { frames: 0.004, ..Default::default() }, 2.4, 1.0, 24.0, no_scale),
        // video -> 6 scene clips
        cpu_op("scene_split", 2.0, 4.0, 8.0, CostW { frames: 0.002, ..Default::default() }, 1.2, 6.0, 10.0,
            [1.0 / 6.0, 1.0, 1.0, 1.0 / 6.0]),
        cpu_op("sample_frames", 1.0, 2.0, 26.0, CostW { frames: 0.01, konst: 0.2, ..Default::default() }, 1.2, 1.0, 5.0, no_scale),
        // --- stage 2: aesthetic filtering (CLIP, NPU) -----------------------
        vision_op("clip_score", 26_000.0, 0.7, 5.0, 6000.0),
        // --- stage 3: OCR-based text filtering (CRAFT, NPU) -----------------
        vision_op("text_detect", 30_000.0, 0.85, 5.0, 5000.0),
        cpu_op("quality_filter", 1.0, 1.0, 60.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 0.9, 4.0, no_scale),
        // --- stage 4: LLM captioning (Qwen2.5-VL-7B, NPU) -------------------
        OperatorSpec {
            name: "caption".into(),
            kind: OperatorKind::AccelAsync,
            cpu: 8.0,
            mem_gb: 32.0,
            accels: 1,
            fanout: 1.0,
            out_mb: 0.02,
            start_s: 8.0,
            stop_s: 2.0,
            cold_s: 30.0,
            tunable: true,
            config_space: ConfigSpace::llm_engine(),
            service: ServiceModel::Accel {
                peak_tok_rate: 4600.0,
                batch_half: 12.0,
                decode_weight: 4.0,
                prefix_share: 0.4,
                mem_base_mb: 20000.0,
                kv_mb_per_token: 0.03,
                act_mb_per_token: 2.6,
                mem_noise_sigma: 0.03,
            },
            features: FeatureExtractor::LlmTokens,
            child_scale: [1.0; 4],
            queue_cap: 512,
        },
        cpu_op("package", 0.5, 1.0, 40.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 1.0, no_scale),
    ];
    PipelineSpec::chain("video", ops)
}

fn ln(x: f64) -> f64 {
    x.ln()
}

/// Short-form clips: 10–30 s, ≤720p.  tokens_in is the *vision-token* load
/// per video (sampled frames × patches); scene_split divides it per clip.
fn short_form() -> ItemDist {
    ItemDist {
        tokens_in: (ln(5_400.0), 0.20),
        tokens_out: (ln(480.0), 0.25),
        pixels_m: (ln(0.9), 0.20),
        frames: (ln(600.0), 0.30),
        size_mb: (ln(18.0), 0.4),
    }
}

/// Long-form videos: 5–10 min, 1080p–4K.
fn long_form() -> ItemDist {
    ItemDist {
        tokens_in: (ln(24_000.0), 0.18),
        tokens_out: (ln(900.0), 0.18),
        pixels_m: (ln(4.5), 0.35),
        frames: (ln(10_800.0), 0.25),
        size_mb: (ln(420.0), 0.4),
    }
}

/// The two-regime video trace, scaled to `n_videos` total (paper: ~410k).
pub fn trace(n_videos: u64) -> PhasedTrace {
    let short = (n_videos as f64 * 0.65) as u64;
    PhasedTrace::new(vec![
        Phase { regime: 0, count: short, sampler: short_form() },
        Phase { regime: 1, count: n_videos - short, sampler: long_form() },
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn pipeline_shape_matches_paper() {
        let p = pipeline();
        assert_eq!(p.n_ops(), 9, "9 operators across four stages");
        let npu: Vec<_> = p.operators.iter().filter(|o| o.accels > 0).collect();
        assert_eq!(npu.len(), 3, "CLIP + CRAFT + captioning on NPU");
        assert_eq!(npu[2].name, "caption");
        let cpu_count = p.operators.iter().filter(|o| o.accels == 0).count();
        assert_eq!(cpu_count, 6, "remaining six CPU-bound");
    }

    #[test]
    fn long_form_is_much_heavier() {
        let s = short_form();
        let l = long_form();
        assert!(l.mean_tokens_in() > 3.0 * s.mean_tokens_in());
        // long-form raw size stresses the network (placement matters more
        // on the video pipeline — Fig. 3)
        assert!(l.size_mb.0 > s.size_mb.0 + 2.0);
    }

    #[test]
    fn trace_two_regimes() {
        let t = trace(1000);
        assert_eq!(t.n_regimes(), 2);
        assert_eq!(t.total(), 1000);
    }
}
