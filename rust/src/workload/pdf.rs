//! PDF curation pipeline + document trace (paper §8.1):
//! 17 operators across five stages — file I/O, parsing & layout detection,
//! block segmentation, modality-specific LLM OCR (3 NPU operators), and
//! aggregation — expanding each document into ~120 content blocks.
//! Trace: three document types processed sequentially (academic papers,
//! annual reports, financial reports).

use crate::config::{
    ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec, ServiceModel,
};
use crate::sim::ItemAttrs;
use crate::workload::{ItemDist, Phase, PhasedTrace};

/// Nominal source-item attrs (first-regime means) used by the CLI,
/// benches, and tests — the single definition point.
pub fn src_attrs() -> ItemAttrs {
    ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 }
}

fn cpu_op(
    name: &str,
    cpu: f64,
    mem_gb: f64,
    base_rate: f64,
    cost: CostW,
    ref_cost: f64,
    fanout: f64,
    out_mb: f64,
    child_scale: [f64; 4],
) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::CpuSync,
        cpu,
        mem_gb,
        accels: 0,
        fanout,
        out_mb,
        start_s: 2.0,
        stop_s: 1.0,
        cold_s: 4.0,
        tunable: false,
        config_space: ConfigSpace::default(),
        service: ServiceModel::Cpu { base_rate, ref_cost, cost },
        features: FeatureExtractor::Cost,
        child_scale,
        queue_cap: 256,
    }
}

fn llm_ocr_op(name: &str, peak_tok_rate: f64, prefix_share: f64) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::AccelAsync,
        cpu: 8.0,
        mem_gb: 32.0,
        accels: 1,
        fanout: 1.0,
        out_mb: 0.05,
        start_s: 8.0,
        stop_s: 2.0,
        // LLM engine restart: weight load + warmup (the paper's h_cold).
        cold_s: 25.0,
        tunable: true,
        config_space: ConfigSpace::llm_engine(),
        service: ServiceModel::Accel {
            peak_tok_rate,
            batch_half: 12.0,
            decode_weight: 4.0,
            prefix_share,
            mem_base_mb: 18000.0,
            kv_mb_per_token: 0.025,
            act_mb_per_token: 2.8,
            mem_noise_sigma: 0.03,
        },
        features: FeatureExtractor::LlmTokens,
        child_scale: [1.0; 4],
        queue_cap: 512,
    }
}

/// The 17-operator PDF curation pipeline.
pub fn pipeline() -> PipelineSpec {
    let no_scale = [1.0; 4];
    let ops = vec![
        // --- stage 1: file I/O -------------------------------------------
        cpu_op("fetch", 0.5, 1.0, 20.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.4, no_scale),
        cpu_op("decrypt", 0.5, 1.0, 16.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.4, no_scale),
        // --- stage 2: parsing + layout detection -------------------------
        cpu_op("parse_pdf", 2.0, 4.0, 4.0, CostW { frames: 1.0, konst: 2.0, ..Default::default() }, 14.0, 1.0, 0.6, no_scale),
        cpu_op("layout_detect", 4.0, 6.0, 2.2, CostW { frames: 1.0, konst: 1.0, ..Default::default() }, 13.0, 1.0, 0.7, no_scale),
        // --- stage 3: block segmentation ----------------------------------
        // doc -> 12 pages
        cpu_op("split_pages", 1.0, 2.0, 10.0, CostW { frames: 1.0, ..Default::default() }, 12.0, 12.0, 0.5,
            [1.0 / 12.0, 1.0 / 12.0, 1.0 / 12.0, 1.0 / 12.0]),
        cpu_op("render_page", 2.0, 3.0, 14.0, CostW { pixels_m: 1.0, konst: 0.2, ..Default::default() }, 1.2, 1.0, 1.2, no_scale),
        // page -> 10 blocks
        cpu_op("detect_blocks", 2.0, 2.0, 9.0, CostW { pixels_m: 1.0, konst: 0.1, ..Default::default() }, 1.1, 10.0, 0.15,
            [0.1, 0.1, 0.1, 1.0]),
        cpu_op("classify_block", 1.0, 1.0, 70.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.15, no_scale),
        // only ~55% of blocks need model-based OCR (text crops OCR'd fast path)
        cpu_op("route_modality", 0.5, 1.0, 150.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 0.55, 0.15, no_scale),
        // --- stage 4: modality-specific OCR (NPU) --------------------------
        llm_ocr_op("text_ocr", 5200.0, 0.55),
        llm_ocr_op("table_ocr", 4200.0, 0.30),
        llm_ocr_op("formula_ocr", 4800.0, 0.20),
        // --- stage 5: aggregation ------------------------------------------
        cpu_op("merge_blocks", 1.0, 1.0, 90.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.08, no_scale),
        cpu_op("dedup", 1.0, 2.0, 80.0, CostW { tokens_out: 0.004, konst: 0.5, ..Default::default() }, 1.0, 0.95, 0.08, no_scale),
        cpu_op("quality_filter", 1.0, 1.0, 100.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 0.9, 0.08, no_scale),
        // ~56 surviving blocks aggregate back into one document record
        cpu_op("aggregate_doc", 1.0, 2.0, 110.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0 / 56.4, 2.0,
            [56.4, 56.4, 1.0, 12.0]),
        cpu_op("write_out", 0.5, 1.0, 12.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 2.0, no_scale),
    ];
    PipelineSpec::chain("pdf", ops)
}

/// Document distributions per type.  tokens_* are *document totals*; the
/// split/detect stages scale them down to per-block loads (÷120).
fn academic() -> ItemDist {
    ItemDist {
        tokens_in: (ln(36_000.0), 0.18),
        tokens_out: (ln(7_200.0), 0.18),
        pixels_m: (ln(12.0), 0.25),
        frames: (ln(12.0), 0.20),
        size_mb: (ln(2.0), 0.4),
    }
}

/// Annual reports: long, table-heavy documents.
fn annual_report() -> ItemDist {
    ItemDist {
        tokens_in: (ln(96_000.0), 0.16),
        tokens_out: (ln(19_200.0), 0.16),
        pixels_m: (ln(30.0), 0.25),
        frames: (ln(30.0), 0.20),
        size_mb: (ln(8.0), 0.4),
    }
}

/// Financial reports: short, dense numeric pages.
fn financial_report() -> ItemDist {
    ItemDist {
        tokens_in: (ln(12_000.0), 0.16),
        tokens_out: (ln(2_400.0), 0.16),
        pixels_m: (ln(8.0), 0.25),
        frames: (ln(8.0), 0.20),
        size_mb: (ln(1.5), 0.4),
    }
}

fn ln(x: f64) -> f64 {
    x.ln()
}

/// The three-regime PDF trace, scaled to `n_docs` total (paper: ~200k).
pub fn trace(n_docs: u64) -> PhasedTrace {
    let a = (n_docs as f64 * 0.4) as u64;
    let b = (n_docs as f64 * 0.35) as u64;
    let c = n_docs - a - b;
    PhasedTrace::new(vec![
        Phase { regime: 0, count: a, sampler: academic() },
        Phase { regime: 1, count: b, sampler: annual_report() },
        Phase { regime: 2, count: c, sampler: financial_report() },
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn pipeline_shape_matches_paper() {
        let p = pipeline();
        assert_eq!(p.n_ops(), 17, "17 operators across five stages");
        let npu_ops: Vec<_> = p.operators.iter().filter(|o| o.accels > 0).collect();
        assert_eq!(npu_ops.len(), 3, "three LLM-based OCR operators on NPU");
        assert!(npu_ops.iter().all(|o| o.tunable));
        // ~120 content blocks per document at the OCR stages
        let (d, d_o) = p.amplification();
        let ids = p.interner();
        let ocr_idx = ids.op("text_ocr").idx();
        assert!((d[ocr_idx] - 66.0).abs() < 10.0, "blocks reaching OCR: {}", d[ocr_idx]);
        let blocks_idx = ids.op("classify_block").idx();
        assert!((d[blocks_idx] - 120.0).abs() < 1.0, "~120 blocks/doc: {}", d[blocks_idx]);
        assert!((d_o - 1.0).abs() < 0.15, "one output doc per input doc: {d_o}");
    }

    #[test]
    fn regimes_have_distinct_block_loads() {
        // per-block tokens_in = doc_tokens / 120
        let am = academic().mean_tokens_in() / 120.0;
        let an = annual_report().mean_tokens_in() / 120.0;
        let fi = financial_report().mean_tokens_in() / 120.0;
        assert!(an > 1.8 * am, "annual blocks much heavier: {am} vs {an}");
        assert!(am > 1.3 * fi, "academic heavier than financial: {am} vs {fi}");
    }

    #[test]
    fn trace_phases_sequential() {
        let mut t = trace(100);
        let mut rng = crate::rngx::Rng::new(0);
        let mut seen = Vec::new();
        while let Some(i) = t.next_item(&mut rng) {
            seen.push(i.regime);
        }
        assert_eq!(seen.len(), 100);
        assert_eq!(t.n_regimes(), 3);
        // strictly non-decreasing regime sequence
        assert!(seen.windows(2).all(|w| w[0] <= w[1]));
    }
}
