//! Speech curation pipeline + trace: the repo's first *branching* DAG
//! workload.  A clip is demuxed and decoded into utterance segments, each
//! segment **forks** into two accelerator branches — ASR transcription and
//! visual captioning — whose partial results **join** back by segment id
//! for transcript/caption alignment before a CPU quality filter:
//!
//! ```text
//! demux -> decode --+--> asr -----+--> align_merge -> quality_filter
//!                   +--> caption -+
//! ```
//!
//! Both branches see every decoded segment (fork = replication), so the
//! MILP must split the accelerator pool across two modality branches that
//! each carry the full replicated volume, and the join's bounded buffer is
//! where branch-rate imbalance turns into backpressure — the scheduling
//! structure TCM-Serve/DIP-style modality parallelism exposes.
//!
//! Trace: three regimes processed sequentially — long-form podcasts
//! (audio-heavy), recorded lectures (slide/visual-heavy), and short-form
//! clips (light on both axes).

use crate::config::{
    ConfigSpace, CostW, FeatureExtractor, OperatorKind, OperatorSpec, PipelineSpec, ServiceModel,
};
use crate::sim::ItemAttrs;
use crate::workload::{ItemDist, Phase, PhasedTrace};

/// Nominal source-item attrs (first-regime means) used by the CLI,
/// benches, and tests — the single definition point.
pub fn src_attrs() -> ItemAttrs {
    ItemAttrs { tokens_in: 14_000.0, tokens_out: 3_600.0, pixels_m: 0.25, frames: 900.0 }
}

fn cpu_op(
    name: &str,
    cpu: f64,
    mem_gb: f64,
    base_rate: f64,
    cost: CostW,
    ref_cost: f64,
    fanout: f64,
    out_mb: f64,
    child_scale: [f64; 4],
) -> OperatorSpec {
    OperatorSpec {
        name: name.into(),
        kind: OperatorKind::CpuSync,
        cpu,
        mem_gb,
        accels: 0,
        fanout,
        out_mb,
        start_s: 2.0,
        stop_s: 1.0,
        cold_s: 4.0,
        tunable: false,
        config_space: ConfigSpace::default(),
        service: ServiceModel::Cpu { base_rate, ref_cost, cost },
        features: FeatureExtractor::Cost,
        child_scale,
        queue_cap: 256,
    }
}

/// ASR transcription (whisper-class encoder/decoder on NPU): decode-heavy
/// token generation over the audio-token stream.
fn asr_op() -> OperatorSpec {
    OperatorSpec {
        name: "asr".into(),
        kind: OperatorKind::AccelAsync,
        cpu: 6.0,
        mem_gb: 24.0,
        accels: 1,
        // Branches between the fork and the join must preserve item ids,
        // so both accelerator branches are strictly record-to-record.
        fanout: 1.0,
        out_mb: 0.05,
        start_s: 6.0,
        stop_s: 2.0,
        cold_s: 18.0,
        tunable: true,
        config_space: ConfigSpace::llm_engine(),
        service: ServiceModel::Accel {
            peak_tok_rate: 9000.0,
            batch_half: 12.0,
            decode_weight: 3.0,
            prefix_share: 0.10,
            mem_base_mb: 12000.0,
            kv_mb_per_token: 0.02,
            act_mb_per_token: 1.8,
            mem_noise_sigma: 0.03,
        },
        features: FeatureExtractor::LlmTokens,
        child_scale: [1.0; 4],
        queue_cap: 384,
    }
}

/// Visual captioning of the segment's sampled frames (VLM on NPU).
fn caption_op() -> OperatorSpec {
    OperatorSpec {
        name: "caption".into(),
        kind: OperatorKind::AccelAsync,
        cpu: 6.0,
        mem_gb: 24.0,
        accels: 1,
        fanout: 1.0,
        out_mb: 0.05,
        start_s: 6.0,
        stop_s: 2.0,
        cold_s: 15.0,
        tunable: true,
        config_space: ConfigSpace::llm_engine(),
        service: ServiceModel::Accel {
            peak_tok_rate: 16_000.0,
            batch_half: 10.0,
            decode_weight: 1.5,
            prefix_share: 0.10,
            mem_base_mb: 9000.0,
            kv_mb_per_token: 0.015,
            act_mb_per_token: 1.4,
            mem_noise_sigma: 0.025,
        },
        features: FeatureExtractor::Vision,
        child_scale: [1.0; 4],
        queue_cap: 384,
    }
}

/// The 6-operator speech curation DAG (fork after decode, join before the
/// quality filter).
pub fn pipeline() -> PipelineSpec {
    let no_scale = [1.0; 4];
    let seg = 1.0 / 3.0; // decode splits a clip into 3 utterance segments
    let ops = vec![
        // 0: container demux (cheap, record-at-a-time)
        cpu_op("demux", 0.5, 1.0, 25.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 1.0, 8.0, no_scale),
        // 1: audio/video decode + utterance segmentation — the fork point:
        //    each segment is replicated onto both accelerator branches.
        cpu_op("decode", 4.0, 8.0, 4.0, CostW { frames: 0.003, ..Default::default() }, 2.0, 3.0, 16.0,
            [seg, seg, 1.0, seg]),
        // 2: ASR branch (NPU)
        asr_op(),
        // 3: captioning branch (NPU)
        caption_op(),
        // 4: transcript/caption alignment — the join (in-degree 2)
        cpu_op("align_merge", 1.0, 2.0, 60.0, CostW { tokens_out: 0.002, konst: 1.0, ..Default::default() }, 1.0, 1.0, 0.1, no_scale),
        // 5: joint audio/visual quality filter
        cpu_op("quality_filter", 1.0, 1.0, 80.0, CostW { konst: 1.0, ..Default::default() }, 1.0, 0.9, 0.1, no_scale),
    ];
    PipelineSpec {
        name: "speech".into(),
        operators: ops,
        edges: vec![(0, 1), (1, 2), (1, 3), (2, 4), (3, 4), (4, 5)],
    }
}

fn ln(x: f64) -> f64 {
    x.ln()
}

/// Long-form podcasts: dense speech, negligible visuals.  tokens_in is the
/// audio-token load per clip (decode divides it per segment); tokens_out
/// the transcript length.
fn podcast() -> ItemDist {
    ItemDist {
        tokens_in: (ln(14_000.0), 0.20),
        tokens_out: (ln(3_600.0), 0.20),
        pixels_m: (ln(0.25), 0.25),
        frames: (ln(900.0), 0.25),
        size_mb: (ln(60.0), 0.4),
    }
}

/// Recorded lectures: long, slide-heavy — the captioning branch carries
/// the weight while speech stays moderate.
fn lecture() -> ItemDist {
    ItemDist {
        tokens_in: (ln(9_000.0), 0.18),
        tokens_out: (ln(2_200.0), 0.18),
        pixels_m: (ln(2.2), 0.30),
        frames: (ln(5_400.0), 0.25),
        size_mb: (ln(220.0), 0.4),
    }
}

/// Short-form clips: light on both branches.
fn short_clip() -> ItemDist {
    ItemDist {
        tokens_in: (ln(1_800.0), 0.22),
        tokens_out: (ln(450.0), 0.25),
        pixels_m: (ln(0.9), 0.20),
        frames: (ln(450.0), 0.30),
        size_mb: (ln(25.0), 0.4),
    }
}

/// The three-regime speech trace, scaled to `n_clips` total.
pub fn trace(n_clips: u64) -> PhasedTrace {
    let a = (n_clips as f64 * 0.40) as u64;
    let b = (n_clips as f64 * 0.35) as u64;
    PhasedTrace::new(vec![
        Phase { regime: 0, count: a, sampler: podcast() },
        Phase { regime: 1, count: b, sampler: lecture() },
        Phase { regime: 2, count: n_clips - a - b, sampler: short_clip() },
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn pipeline_is_a_fork_join_dag() {
        let p = pipeline();
        assert_eq!(p.n_ops(), 6);
        assert!(p.validate().is_ok(), "{:?}", p.validate());
        assert_eq!(p.out_edges(1).len(), 2, "decode forks into two branches");
        assert!(p.is_join(4), "align_merge joins the branches");
        assert_eq!(p.sinks(), vec![5]);
        let npu: Vec<_> = p.operators.iter().filter(|o| o.accels > 0).collect();
        assert_eq!(npu.len(), 2, "ASR + captioning on NPU");
        assert!(npu.iter().all(|o| o.tunable));
        // Branch operators must preserve lineage ids for the join.
        assert_eq!(p.operators[2].fanout, 1.0);
        assert_eq!(p.operators[3].fanout, 1.0);
    }

    #[test]
    fn amplification_replicates_then_aligns() {
        let p = pipeline();
        let (d, d_o) = p.amplification();
        // 3 segments per clip on BOTH branches; the join consumes one
        // merged record per aligned pair.
        assert_eq!(d, vec![1.0, 1.0, 3.0, 3.0, 3.0, 3.0]);
        assert!((d_o - 2.7).abs() < 1e-9, "3 segments x 0.9 filter pass: {d_o}");
        let vols = p.edge_volumes();
        assert_eq!(vols, vec![1.0, 3.0, 3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn regimes_load_opposite_branches() {
        let po = podcast();
        let le = lecture();
        let sh = short_clip();
        // Podcasts dominate the ASR branch, lectures the caption branch.
        assert!(po.mean_tokens_in() > 1.4 * le.mean_tokens_in());
        assert!(le.pixels_m.0 > po.pixels_m.0 + 1.5);
        assert!(po.mean_tokens_in() > 5.0 * sh.mean_tokens_in());
    }

    #[test]
    fn trace_three_sequential_regimes() {
        let mut t = trace(200);
        assert_eq!(t.n_regimes(), 3);
        assert_eq!(t.total(), 200);
        let mut rng = crate::rngx::Rng::new(0);
        let mut seen = Vec::new();
        while let Some(i) = t.next_item(&mut rng) {
            seen.push(i.regime);
        }
        assert_eq!(seen.len(), 200);
        assert!(seen.windows(2).all(|w| w[0] <= w[1]));
    }
}
