//! Property-testing helper (proptest is unavailable in the offline crate
//! set).  `props::check` runs a closure over N seeded random cases and, on
//! failure, reports the seed so the case can be replayed exactly.

pub mod props {
    use crate::rngx::Rng;

    /// Run `f` for `cases` seeded RNGs derived from `root_seed`; panic with
    /// the failing seed on the first error returned.
    pub fn check<F>(root_seed: u64, cases: usize, mut f: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in 0..cases {
            let seed = root_seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(case as u64);
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property failed at case {case} (seed {seed}): {msg}");
            }
        }
    }

    /// Assert helper producing `Result` for use inside `check` closures.
    #[macro_export]
    macro_rules! prop_assert {
        ($cond:expr, $($fmt:tt)*) => {
            if !($cond) {
                return Err(format!($($fmt)*));
            }
        };
    }
}
