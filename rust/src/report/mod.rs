//! Paper-style table / figure emitters: markdown + CSV under `results/`.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A simple table: header row + data rows, rendered as markdown.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(out, "|{}|", vec!["---"; self.header.len()].join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    /// Print to stdout and persist markdown+csv under `results/`.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.to_markdown());
        let _ = fs::create_dir_all("results");
        let _ = fs::write(Path::new("results").join(format!("{slug}.md")), self.to_markdown());
        let _ = fs::write(Path::new("results").join(format!("{slug}.csv")), self.to_csv());
    }
}

/// Format helpers.
pub fn fx(v: f64) -> String {
    format!("{v:.2}x")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn pct(v: f64) -> String {
    format!("{v:.1}%")
}

/// Emit an (x, series...) CSV "figure" under `results/` and print a compact
/// ASCII sparkline per series.
pub fn emit_series(slug: &str, title: &str, x_label: &str, series: &[(String, Vec<(f64, f64)>)]) {
    let mut csv = String::new();
    let _ = writeln!(csv, "{x_label},{}", series.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>().join(","));
    if let Some((_, first)) = series.first() {
        for (idx, (x, _)) in first.iter().enumerate() {
            let mut line = format!("{x}");
            for (_, pts) in series {
                let v = pts.get(idx).map(|p| p.1).unwrap_or(f64::NAN);
                let _ = write!(line, ",{v}");
            }
            let _ = writeln!(csv, "{line}");
        }
    }
    let _ = fs::create_dir_all("results");
    let _ = fs::write(Path::new("results").join(format!("{slug}.csv")), csv);
    println!("== {title} ==");
    for (name, pts) in series {
        print!("{name:>24}: ");
        let max = pts.iter().map(|p| p.1).fold(1e-12, f64::max);
        const BARS: &[char] = &[' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let step = (pts.len() / 60).max(1);
        for chunk in pts.chunks(step) {
            let v = chunk.iter().map(|p| p.1).sum::<f64>() / chunk.len() as f64;
            let idx = ((v / max) * 8.0).round().clamp(0.0, 8.0) as usize;
            print!("{}", BARS[idx]);
        }
        println!("  (peak {:.2})", max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["Method", "PDF", "Video"]);
        t.row(vec!["Static".into(), fx(1.0), fx(1.0)]);
        t.row(vec!["Trident".into(), fx(2.01), fx(1.88)]);
        let md = t.to_markdown();
        assert!(md.contains("| Static | 1.00x | 1.00x |"));
        assert!(md.contains("### Demo"));
        let csv = t.to_csv();
        assert!(csv.starts_with("Method,PDF,Video"));
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        let mut t = Table::new("Bad", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
