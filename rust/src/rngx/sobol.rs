//! Sobol low-discrepancy sequence (Joe–Kuo direction numbers, ≤ 10 dims).
//!
//! Used for the BO initial design and the Sobol-based Random-Search baseline
//! of Table 5 (the paper cites Sobol-based random search [27]).  Gray-code
//! construction after Bratley & Fox; direction numbers from the
//! `new-joe-kuo-6` table (first 10 dimensions).

const MAX_DIM: usize = 10;
const BITS: usize = 32;

/// (s, a, m...) primitive-polynomial parameters for dimensions 2..=10.
const JOE_KUO: [(u32, u32, &[u32]); 9] = [
    (1, 0, &[1]),
    (2, 1, &[1, 3]),
    (3, 1, &[1, 3, 1]),
    (3, 2, &[1, 1, 1]),
    (4, 1, &[1, 1, 3, 3]),
    (4, 4, &[1, 3, 5, 13]),
    (5, 2, &[1, 1, 5, 5, 17]),
    (5, 4, &[1, 1, 5, 5, 5]),
    (5, 7, &[1, 1, 7, 11, 19]),
];

/// Incremental Sobol sequence generator over the unit hypercube `[0,1)^d`.
pub struct Sobol {
    dim: usize,
    index: u64,
    /// Current integer state per dimension.
    x: Vec<u32>,
    /// Direction numbers: v[d][b].
    v: Vec<[u32; BITS]>,
}

impl Sobol {
    /// Panics if `dim == 0 || dim > 10`.
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1 && dim <= MAX_DIM, "Sobol supports 1..=10 dims, got {dim}");
        let mut v = Vec::with_capacity(dim);
        // Dimension 1: van der Corput, v_k = 1 << (31 - k).
        let mut v0 = [0u32; BITS];
        for (k, slot) in v0.iter_mut().enumerate() {
            *slot = 1 << (31 - k);
        }
        v.push(v0);
        for d in 1..dim {
            let (s, a, m) = JOE_KUO[d - 1];
            let s = s as usize;
            let mut vd = [0u32; BITS];
            for k in 0..BITS {
                if k < s {
                    vd[k] = m[k] << (31 - k);
                } else {
                    let mut val = vd[k - s] ^ (vd[k - s] >> s);
                    for j in 1..s {
                        if (a >> (s - 1 - j)) & 1 == 1 {
                            val ^= vd[k - j];
                        }
                    }
                    vd[k] = val;
                }
            }
            v.push(vd);
        }
        Sobol { dim, index: 0, x: vec![0; dim], v }
    }

    /// Next point in the sequence (the first returned point is index 1,
    /// skipping the degenerate all-zeros origin).
    pub fn next_point(&mut self) -> Vec<f64> {
        self.index += 1;
        // Gray-code: flip the direction number of the lowest zero bit of
        // the previous index.
        let c = (self.index - 1).trailing_ones() as usize;
        let c = c.min(BITS - 1);
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c];
        }
        self.x
            .iter()
            .map(|&xi| xi as f64 / 4294967296.0)
            .collect()
    }

    /// Generate `n` points as rows.
    pub fn take_points(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.next_point()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_dimension_is_van_der_corput() {
        let mut s = Sobol::new(1);
        let got: Vec<f64> = (0..7).map(|_| s.next_point()[0]).collect();
        let expect = [0.5, 0.75, 0.25, 0.375, 0.875, 0.625, 0.125];
        for (g, e) in got.iter().zip(expect.iter()) {
            assert!((g - e).abs() < 1e-12, "{g} vs {e}");
        }
    }

    #[test]
    fn second_dimension_known_prefix() {
        let mut s = Sobol::new(2);
        let pts = s.take_points(3);
        // Standard Sobol 2-d prefix: (0.5,0.5), (0.75,0.25), (0.25,0.75)
        assert!((pts[0][1] - 0.5).abs() < 1e-12);
        assert!((pts[1][1] - 0.25).abs() < 1e-12);
        assert!((pts[2][1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn points_in_unit_cube_all_dims() {
        for d in 1..=10 {
            let mut s = Sobol::new(d);
            for p in s.take_points(200) {
                assert_eq!(p.len(), d);
                assert!(p.iter().all(|&x| (0.0..1.0).contains(&x)));
            }
        }
    }

    #[test]
    fn low_discrepancy_beats_random_striping() {
        // Each half of each axis should get ~half the points much more
        // precisely than iid-uniform would.
        let mut s = Sobol::new(5);
        let pts = s.take_points(1024);
        for d in 0..5 {
            let lo = pts.iter().filter(|p| p[d] < 0.5).count();
            assert!(
                (lo as i64 - 512).unsigned_abs() <= 1,
                "dim {d}: {lo}/1024 below 0.5"
            );
        }
    }

    #[test]
    fn no_duplicate_points_in_prefix() {
        let mut s = Sobol::new(3);
        let pts = s.take_points(512);
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                assert_ne!(pts[i], pts[j], "duplicate at {i},{j}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_dim_11() {
        Sobol::new(11);
    }
}
