//! Deterministic random-number utilities.
//!
//! The offline vendored crate set has no `rand`/`rand_distr`, so Trident
//! carries its own small, fully deterministic RNG stack:
//!
//! * [`Rng`] — xoshiro256++ seeded through SplitMix64 (the reference
//!   seeding procedure), with uniform/normal/lognormal/exponential/
//!   categorical samplers;
//! * [`sobol`] — a Joe–Kuo Sobol low-discrepancy sequence (up to 10
//!   dimensions) used by the adaptation layer's search baselines and BO
//!   initial design.
//!
//! Every simulation entity derives its stream from a root seed via
//! [`Rng::fork`], so runs are reproducible regardless of scheduling order.

pub mod sobol;

/// xoshiro256++ PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 so even seeds 0/1/2... give well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (stable under call-site reordering).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64).min(n as f64 - 1.0) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal_std(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal_std()
    }

    /// Log-normal parameterized by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Truncated normal: resample until inside [lo, hi] (bounded retries,
    /// then clamp) — good enough for workload feature generation.
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        for _ in 0..16 {
            let v = self.normal(mean, std);
            if v >= lo && v <= hi {
                return v;
            }
        }
        self.normal(mean, std).clamp(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_is_independent_of_parent_consumption_order() {
        let mut p1 = Rng::new(7);
        let c1: Vec<u64> = {
            let mut c = p1.fork(3);
            (0..8).map(|_| c.next_u64()).collect()
        };
        let mut p2 = Rng::new(7);
        let mut c2 = p2.fork(3);
        for v in c1 {
            assert_eq!(v, c2.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal(3.0, 2.0);
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[0] as f64 / 1e5 - 0.1).abs() < 0.01);
        assert!((counts[2] as f64 / 1e5 - 0.6).abs() < 0.01);
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            let v = r.normal_clamped(0.0, 10.0, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&v));
        }
    }
}
