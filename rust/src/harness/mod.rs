//! Parallel multi-seed experiment harness: execute variant × seed grids
//! across OS threads and aggregate the [`RunReport`]s into mean ± std
//! summaries.
//!
//! This is the single entry point for grid-shaped evaluation — the CLI's
//! `compare` / `sweep` subcommands and the paper-reproduction benches all
//! fan out through [`run_grid`].  Every grid cell owns its coordinator
//! (and RNG chain) seeded purely from the [`Job`], cells never share
//! mutable state, and results land in index-addressed slots, so cell
//! outputs do not depend on worker count or OS scheduling
//! (`tests/policy_parity.rs` pins this).
//!
//! One caveat: the Trident MILP is an *anytime* solver with a wall-clock
//! budget (paper §7).  A solve that exhausts its search tree within the
//! budget (`Status::Optimal`, the common case at evaluation sizes) is
//! deterministic; a budget-bound solve returns the incumbent at cutoff,
//! which heavy core oversubscription can perturb.  For strict
//! reproducibility of Trident cells on a loaded host, cap `workers` below
//! the core count or raise `milp_time_budget_ms`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::adaptation::{ConfigTuner, Strategy, TunerConfig};
use crate::config::PipelineSpec;
use crate::coordinator::{Coordinator, Policy, RunReport, Variant};
use crate::runtime::GpBackend;
use crate::sim::ItemAttrs;

/// Default simulated-time cap for run-to-completion cells (the paper's
/// offline paradigm: fixed dataset, fastest finish wins).
pub const MAX_SIM_S: f64 = 4.0 * 3600.0;

/// One grid cell: a variant at a seed.  Cells with the same `label` are
/// aggregated together by [`summarize`].
#[derive(Debug, Clone)]
pub struct Job {
    pub label: String,
    pub variant: Variant,
    pub seed: u64,
    /// Simulated-time budget, seconds.
    pub max_s: f64,
    /// Run until the trace drains (true) or for exactly `max_s` (false).
    pub until_drained: bool,
}

impl Job {
    /// A run-to-completion cell (offline paradigm, [`MAX_SIM_S`] cap).
    pub fn new(label: impl Into<String>, variant: Variant, seed: u64) -> Job {
        Job { label: label.into(), variant, seed, max_s: MAX_SIM_S, until_drained: true }
    }

    /// A fixed-duration cell (`duration_s` of simulated time).
    pub fn timed(label: impl Into<String>, variant: Variant, seed: u64, duration_s: f64) -> Job {
        Job { label: label.into(), variant, seed, max_s: duration_s, until_drained: false }
    }
}

/// Worker-count default: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` and return (result, wall milliseconds).  Shared by the bench
/// subcommands so every trajectory number is timed the same way.
pub fn stopwatch_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

/// Execute every job on a pool of `workers` OS threads.  `factory` builds
/// the coordinator for a cell *inside* the worker thread (coordinators are
/// not `Send` — they own the trace generator), keyed by the cell index and
/// job.  Reports come back in job order, independent of worker count.
pub fn run_grid<F>(jobs: &[Job], workers: usize, factory: F) -> Vec<RunReport>
where
    F: Fn(usize, &Job) -> Coordinator + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<RunReport>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = &jobs[i];
                let mut coord = factory(i, job);
                let report = if job.until_drained {
                    coord.run_to_completion(job.max_s)
                } else {
                    coord.run(job.max_s)
                };
                slots.lock().unwrap()[i] = Some(report);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job index is claimed by exactly one worker"))
        .collect()
}

/// Mean / population-std / min / max of a metric across seeds.
#[derive(Debug, Clone, Copy)]
pub struct Stat {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stat {
    pub fn of(vals: &[f64]) -> Stat {
        if vals.is_empty() {
            return Stat { mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let min = vals.iter().copied().fold(f64::INFINITY, f64::min);
        let max = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        Stat { mean, std: var.sqrt(), min, max }
    }

    /// "mean ± std" with three decimals.
    pub fn pm(&self) -> String {
        format!("{:.3} ± {:.3}", self.mean, self.std)
    }
}

/// Aggregate of all cells sharing one label (variant across seeds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub label: String,
    pub n: usize,
    pub throughput: Stat,
    pub oom_events: Stat,
    pub oom_downtime_s: Stat,
    pub transitions: Stat,
    pub duration_s: Stat,
    pub items_processed: Stat,
}

/// Group reports by job label (first-seen order) and reduce each metric
/// to mean ± std across the label's seeds.
pub fn summarize(jobs: &[Job], reports: &[RunReport]) -> Vec<Summary> {
    assert_eq!(jobs.len(), reports.len(), "one report per job");
    let mut order: Vec<&str> = Vec::new();
    for j in jobs {
        if !order.iter().any(|l| *l == j.label.as_str()) {
            order.push(j.label.as_str());
        }
    }
    order
        .iter()
        .map(|label| {
            let rs: Vec<&RunReport> = jobs
                .iter()
                .zip(reports)
                .filter(|(j, _)| j.label.as_str() == *label)
                .map(|(_, r)| r)
                .collect();
            let stat = |g: fn(&RunReport) -> f64| -> Stat {
                Stat::of(&rs.iter().map(|&r| g(r)).collect::<Vec<f64>>())
            };
            Summary {
                label: label.to_string(),
                n: rs.len(),
                throughput: stat(|r| r.throughput),
                oom_events: stat(|r| r.oom_events as f64),
                oom_downtime_s: stat(|r| r.oom_downtime_s),
                transitions: stat(|r| r.config_transitions as f64),
                duration_s: stat(|r| r.duration_s),
                items_processed: stat(|r| r.items_processed as f64),
            }
        })
        .collect()
}

/// SCOOT's offline per-operator tuning phase: BO against a sustained
/// isolated-operator evaluation at the *first* regime (the paper tunes
/// offline before the run), then deploy statically.  (Moved here from
/// `benches/common.rs` so the CLI sweep can run SCOOT too; constants are
/// unchanged, so bench results are unchanged.)
pub fn scoot_variant(pipeline: &PipelineSpec, src: ItemAttrs) -> Variant {
    scoot_variant_rooted(pipeline, &[(0, src)])
}

/// SCOOT offline tuning over a merged tenancy: each tenant's operators
/// are tuned against that tenant's own nominal attrs (multi-root
/// propagation), producing initial configs indexed by merged op.
pub fn scoot_variant_merged(
    spec: &PipelineSpec,
    view: &crate::config::TenancyView,
    srcs: &[ItemAttrs],
) -> Variant {
    let roots: Vec<(usize, ItemAttrs)> =
        view.sources.iter().copied().zip(srcs.iter().copied()).collect();
    scoot_variant_rooted(spec, &roots)
}

fn scoot_variant_rooted(pipeline: &PipelineSpec, roots: &[(usize, ItemAttrs)]) -> Variant {
    let backend = GpBackend::from_env();
    let nominal = crate::coordinator::nominal_attrs_rooted(pipeline, roots);
    let mut rng = crate::rngx::Rng::new(99);
    let configs: Vec<Option<Vec<f64>>> = pipeline
        .operators
        .iter()
        .enumerate()
        .map(|(i, o)| {
            if !o.tunable {
                return None;
            }
            let mut tuner = ConfigTuner::new(
                o.config_space.clone(),
                TunerConfig {
                    strategy: Strategy::ConstrainedBo,
                    budget: 30,
                    n_init: 5,
                    eta: 0.6,
                    mem_limit_mb: 65_536.0 - 2048.0,
                    seed: i as u64,
                },
            );
            while !tuner.done() {
                let theta = tuner.next_candidate(&backend);
                let ut = crate::sim::service::true_unit_rate(&o.service, &theta, &nominal[i])
                    * rng.lognormal(0.0, 0.05);
                let mem = crate::sim::service::expected_mem(&o.service, &theta, &nominal[i])
                    * rng.lognormal(0.02, 0.03);
                let oom = mem > 65_536.0;
                tuner.record(theta, ut, mem, oom);
            }
            tuner.best().map(|e| e.theta.clone())
        })
        .collect();
    let mut v = Variant::baseline(Policy::Scoot);
    v.initial_configs = Some(configs);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_of_mean_std() {
        let s = Stat::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 4.0));
        let e = Stat::of(&[]);
        assert_eq!(e.mean, 0.0);
    }

    #[test]
    fn summarize_groups_by_label_in_order() {
        let v = Variant::baseline(Policy::Static);
        let jobs = vec![
            Job::timed("b", v.clone(), 0, 1.0),
            Job::timed("a", v.clone(), 1, 1.0),
            Job::timed("b", v, 2, 1.0),
        ];
        let mk = |thr: f64| RunReport {
            pipeline: "p".into(),
            variant: "v".into(),
            duration_s: 1.0,
            throughput: thr,
            tenants: vec![],
            series: vec![],
            oom_events: 0,
            oom_downtime_s: 0.0,
            config_transitions: 0,
            milp_ms: vec![],
            plans_committed: 0,
            milp_pivots: 0,
            milp_bnb_nodes: 0,
            milp_pricing_rounds: 0,
            milp_columns: 0,
            milp_warm_hit_rate: 0.0,
            milp_phase_ms: [0.0; 4],
            pool_steals: 0,
            pool_epochs: 0,
            pool_wait_ms: 0.0,
            pool_tasks: vec![],
            workers_effective: 0,
            obs_overhead_ms: 0.0,
            adapt_overhead_ms: 0.0,
            estimator_mape: Default::default(),
            cluster_eval: vec![],
            items_processed: 0,
            events: vec![],
            lost_records: 0,
        };
        let reports = vec![mk(1.0), mk(5.0), mk(3.0)];
        let s = summarize(&jobs, &reports);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].label, "b");
        assert_eq!(s[0].n, 2);
        assert!((s[0].throughput.mean - 2.0).abs() < 1e-12);
        assert_eq!(s[1].label, "a");
        assert_eq!(s[1].n, 1);
    }
}
