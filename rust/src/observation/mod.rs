//! Observation layer (paper §4): noise-resilient sustainable-throughput
//! estimation for asynchronous operators.
//!
//! Per operator, a [`CapacityEstimator`] ingests window snapshots from the
//! metrics collector and maintains:
//!
//! * a **two-stage anomaly filter** — stage 1 rejects non-steady-state
//!   windows from runtime signals (utilization below τ_u: upstream
//!   starvation; rapidly draining/growing queues: transient supply
//!   imbalance), stage 2 rejects GP-residual outliers (|z| > τ_z, §4.3);
//! * a **GP regression model** mapping workload descriptors to
//!   per-instance throughput, evaluated through the AOT-compiled PJRT
//!   artifact (Layer 1+2) or the native oracle;
//! * an **EMA cold-start path** (§4.4) active until `n_min` filtered
//!   samples exist, and re-entered after sample invalidation when the
//!   scheduling layer commits a configuration transition (path ⑨).
//!
//! The filter/model stages can be disabled independently, which is exactly
//! the estimator lattice Table 3 compares (true-rate / EMA / GP raw /
//! GP+signal / GP+two-stage).

use crate::config::FeatureExtractor;
use crate::runtime::{fit_hyper, GpBackend};
use crate::sim::OpMetrics;

/// Estimator configuration (subset of `TridentConfig`).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    pub tau_u: f64,
    pub tau_z: f64,
    pub n_min: usize,
    pub window: usize,
    pub ema_alpha: f64,
    /// Queue-trend rejection: |Δq| / max(q_begin, floor) above this is a
    /// transient (draining or backlog-building) window.
    pub queue_trend: f64,
    pub use_gp: bool,
    pub signal_filter: bool,
    pub model_filter: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            tau_u: 0.6,
            tau_z: 3.0,
            n_min: 8,
            window: 64,
            ema_alpha: 0.3,
            queue_trend: 0.6,
            use_gp: true,
            signal_filter: true,
            model_filter: true,
        }
    }
}

impl ObsConfig {
    pub fn from_trident(c: &crate::config::TridentConfig) -> Self {
        ObsConfig {
            tau_u: c.tau_u,
            tau_z: c.tau_z,
            n_min: c.n_min,
            window: c.gp_window,
            ema_alpha: c.ema_alpha,
            ..Default::default()
        }
    }
}

/// Why a sample was rejected (stats / debugging / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Accepted,
    LowUtilization,
    QueueTransient,
    ModelOutlier,
    Empty,
}

/// Filter + model statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ObsStats {
    pub accepted: u64,
    pub rejected_signal: u64,
    pub rejected_model: u64,
    pub invalidations: u64,
}

/// Capacity estimator for one operator.
pub struct CapacityEstimator {
    pub cfg: ObsConfig,
    extractor: FeatureExtractor,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    ema: Option<f64>,
    /// Last raw observation (rate, utilization) — last-resort fallback.
    last_raw: Option<(f64, f64)>,
    /// Consecutive stage-2 rejections (drift detection).
    consec_outliers: u32,
    pub stats: ObsStats,
}

impl CapacityEstimator {
    pub fn new(cfg: ObsConfig, extractor: FeatureExtractor) -> Self {
        CapacityEstimator {
            cfg,
            extractor,
            xs: Vec::new(),
            ys: Vec::new(),
            ema: None,
            last_raw: None,
            consec_outliers: 0,
            stats: ObsStats::default(),
        }
    }

    pub fn n_samples(&self) -> usize {
        self.ys.len()
    }

    pub fn gp_active(&self) -> bool {
        self.cfg.use_gp && self.ys.len() >= self.cfg.n_min
    }

    /// Stage-1 signal filter.
    fn signal_verdict(&self, m: &OpMetrics) -> Verdict {
        if m.records_out == 0 || m.n_active == 0 {
            return Verdict::Empty;
        }
        if !self.cfg.signal_filter {
            return Verdict::Accepted;
        }
        if m.utilization < self.cfg.tau_u {
            return Verdict::LowUtilization;
        }
        let q0 = m.queue_begin as f64;
        let q1 = m.queue_end as f64;
        let delta = (q1 - q0).abs() / q0.max(16.0);
        if delta > self.cfg.queue_trend {
            return Verdict::QueueTransient;
        }
        Verdict::Accepted
    }

    /// Ingest one metrics window; returns the filter verdict.
    pub fn observe(&mut self, m: &OpMetrics, backend: &GpBackend) -> Verdict {
        let y = m.rate_per_inst;
        if y > 0.0 {
            self.last_raw = Some((y, m.utilization));
        }
        let v = self.signal_verdict(m);
        if v != Verdict::Accepted {
            if !matches!(v, Verdict::Empty) {
                self.stats.rejected_signal += 1;
            }
            return v;
        }
        let x = m.gp_features(self.extractor);

        // Stage 2: model-based residual filter (only once the GP is live).
        // Two refinements keep it from fighting the adaptation the layer
        // exists to provide:
        // * rejection only applies where the model is *confident*
        //   (predictive variance well below the prior) — sporadic outliers
        //   live in well-explored regions, regime shifts in unexplored ones;
        // * a run of consecutive rejections is drift, not noise
        //   (cf. DAO-GP-style drift awareness): flush the buffer and accept.
        if self.cfg.use_gp && self.cfg.model_filter && self.gp_active() {
            let hyper = fit_hyper(&self.xs, &self.ys);
            if let Ok(pred) = backend.gp_predict(&self.xs, &self.ys, &[x.clone()], hyper) {
                let (mu, var) = pred[0];
                let prior = hyper.signal_var + hyper.noise_var;
                let confident = var < 0.5 * prior;
                let z = (y - mu) / var.sqrt().max(1e-9);
                if confident && z.abs() > self.cfg.tau_z {
                    self.consec_outliers += 1;
                    if self.consec_outliers >= 6 {
                        // Sustained disagreement = the workload moved.
                        self.xs.clear();
                        self.ys.clear();
                        self.ema = None;
                        self.consec_outliers = 0;
                        // fall through and accept the new-regime sample
                    } else {
                        self.stats.rejected_model += 1;
                        return Verdict::ModelOutlier;
                    }
                } else {
                    self.consec_outliers = 0;
                }
            }
        }

        // Accept: update EMA + GP buffer (sliding window).  The EMA stores
        // a mildly utilization-corrected rate (floor 0.6 = τ_u) so the
        // cold-start path does not read residual slack as low capacity.
        self.stats.accepted += 1;
        let a = self.cfg.ema_alpha;
        let y_corr = y / m.utilization.clamp(self.cfg.tau_u, 1.0);
        self.ema = Some(match self.ema {
            None => y_corr,
            Some(e) => (1.0 - a) * e + a * y_corr,
        });
        self.xs.push(x);
        self.ys.push(y);
        if self.ys.len() > self.cfg.window {
            self.xs.remove(0);
            self.ys.remove(0);
        }
        Verdict::Accepted
    }

    /// Capacity estimate (records/s per instance) at the workload described
    /// by `m`, with a confidence proxy in [0, 1].
    pub fn estimate(&self, m: &OpMetrics, backend: &GpBackend) -> (f64, f64) {
        if self.gp_active() {
            let x = m.gp_features(self.extractor);
            let hyper = fit_hyper(&self.xs, &self.ys);
            if let Ok(pred) = backend.gp_predict(&self.xs, &self.ys, &[x], hyper) {
                let (mu, var) = pred[0];
                let conf = (1.0 - var / (hyper.signal_var + hyper.noise_var)).clamp(0.0, 1.0);
                return (mu.max(1e-6), conf);
            }
        }
        if let Some(e) = self.ema {
            return (e.max(1e-6), 0.3);
        }
        // Last resort: utilization-extrapolated raw rate.
        match self.last_raw {
            Some((y, u)) => ((y / u.max(0.05)).max(1e-6), 0.1),
            None => (1e-6, 0.0),
        }
    }

    /// Sample invalidation on configuration transition (paper §4.4 / path ⑨):
    /// clear the buffer, reset the GP, return to EMA-based estimation.
    pub fn invalidate(&mut self) {
        self.xs.clear();
        self.ys.clear();
        self.ema = None;
        self.stats.invalidations += 1;
    }
}

/// DS2-style "true processing rate" estimator: records per useful
/// (busy) second.  Correct for synchronous operators, systematically wrong
/// for continuous-batching asynchronous ones (Table 3 row 1).
#[derive(Debug, Clone, Default)]
pub struct UsefulTimeEstimator {
    rate: Option<f64>,
    alpha: f64,
}

impl UsefulTimeEstimator {
    pub fn new() -> Self {
        UsefulTimeEstimator { rate: None, alpha: 0.3 }
    }

    pub fn observe(&mut self, m: &OpMetrics) {
        let busy: f64 = m.per_instance.iter().map(|i| i.busy_s).sum();
        let recs: u64 = m.per_instance.iter().map(|i| i.records).sum();
        if busy > 1e-6 && recs > 0 {
            let per_inst_busy = busy / m.n_active.max(1) as f64;
            let per_inst_recs = recs as f64 / m.n_active.max(1) as f64;
            let y = per_inst_recs / per_inst_busy;
            self.rate = Some(match self.rate {
                None => y,
                Some(r) => (1.0 - self.alpha) * r + self.alpha * y,
            });
        }
    }

    pub fn estimate(&self) -> f64 {
        self.rate.unwrap_or(1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::metrics::InstanceMetrics;

    fn metrics(rate: f64, util: f64, q0: usize, q1: usize, tin: f64) -> OpMetrics {
        OpMetrics {
            op: 0,
            window_s: 5.0,
            records_in: 100,
            records_out: (rate * 5.0) as u64,
            rate_per_inst: rate,
            utilization: util,
            queue_begin: q0,
            queue_end: q1,
            queue_avg: (q0 + q1) as f64 / 2.0,
            feat_mean: [tin, tin / 4.0, 0.0, 1.0],
            feat_std: [tin / 10.0, tin / 40.0, 0.0, 0.0],
            peak_mem_mb: 0.0,
            oom_events: 0,
            n_active: 1,
            cluster_samples: vec![],
            per_instance: vec![InstanceMetrics {
                inst: 0,
                node: 0,
                records: (rate * 5.0) as u64,
                busy_s: 5.0 * util,
                active_s: 5.0,
                peak_mem_mb: 0.0,
                oom_events: 0,
                queue_len: q1,
                config_gen: 0,
            }],
        }
    }

    fn backend() -> GpBackend {
        GpBackend::Native
    }

    #[test]
    fn stage1_rejects_starvation_and_transients() {
        let est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        assert_eq!(est.signal_verdict(&metrics(5.0, 0.2, 50, 50, 500.0)), Verdict::LowUtilization);
        assert_eq!(est.signal_verdict(&metrics(5.0, 0.9, 10, 300, 500.0)), Verdict::QueueTransient);
        assert_eq!(est.signal_verdict(&metrics(5.0, 0.9, 300, 10, 500.0)), Verdict::QueueTransient);
        assert_eq!(est.signal_verdict(&metrics(5.0, 0.9, 100, 110, 500.0)), Verdict::Accepted);
    }

    #[test]
    fn ema_before_gp_then_gp_takes_over() {
        let mut est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let b = backend();
        // slight variation so GP hyperparameters are non-degenerate
        for i in 0..3 {
            let y = 4.0 + 0.2 * (i % 3) as f64;
            est.observe(&metrics(y, 0.9, 100, 100, 500.0 + 20.0 * i as f64), &b);
        }
        assert!(!est.gp_active());
        let (e, conf) = est.estimate(&metrics(4.0, 0.9, 100, 100, 500.0), &b);
        assert!((e - 4.2).abs() < 0.6);
        assert!(conf < 0.5);
        for i in 0..10 {
            let y = 4.0 + 0.2 * (i % 3) as f64;
            est.observe(&metrics(y, 0.9, 100, 100, 500.0 + 20.0 * (i % 4) as f64), &b);
        }
        assert!(est.gp_active());
        let (e, conf) = est.estimate(&metrics(4.0, 0.9, 100, 100, 500.0), &b);
        assert!((e - 4.2).abs() < 0.6, "gp estimate {e}");
        assert!(conf > 0.5, "conf {conf}");
    }

    #[test]
    fn gp_conditions_on_workload() {
        // Two workload regimes with different rates; the GP must separate
        // them while an EMA would blur.
        let mut est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let b = backend();
        for _ in 0..12 {
            est.observe(&metrics(8.0, 0.9, 100, 100, 300.0), &b);
            est.observe(&metrics(2.0, 0.9, 100, 100, 1200.0), &b);
        }
        let (short, _) = est.estimate(&metrics(0.0, 0.9, 100, 100, 300.0), &b);
        let (long, _) = est.estimate(&metrics(0.0, 0.9, 100, 100, 1200.0), &b);
        assert!(short > 2.0 * long, "short {short} vs long {long}");
    }

    #[test]
    fn model_filter_rejects_outliers() {
        let mut est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let b = backend();
        // mild variation keeps the GP hyperparameters non-degenerate
        for i in 0..16 {
            let y = 5.0 + 0.2 * (i % 3) as f64;
            est.observe(&metrics(y, 0.95, 100, 100, 500.0 + 15.0 * (i % 4) as f64), &b);
        }
        // An absurd spike passes stage 1 but must fail stage 2.
        let v = est.observe(&metrics(50.0, 0.95, 100, 100, 500.0), &b);
        assert_eq!(v, Verdict::ModelOutlier);
        assert!(est.stats.rejected_model > 0);
        let (e, _) = est.estimate(&metrics(5.2, 0.95, 100, 100, 500.0), &b);
        assert!((e - 5.4).abs() < 1.0, "outlier must not corrupt model: {e}");
    }

    #[test]
    fn sustained_disagreement_is_drift_not_outliers() {
        // A run of consistent "outliers" is a regime shift: the estimator
        // must flush and adapt instead of rejecting forever.
        let mut est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let b = backend();
        for i in 0..16 {
            let y = 5.0 + 0.2 * (i % 3) as f64;
            est.observe(&metrics(y, 0.95, 100, 100, 500.0 + 15.0 * (i % 4) as f64), &b);
        }
        for i in 0..12 {
            let y = 1.0 + 0.05 * (i % 3) as f64; // new, much slower regime
            est.observe(&metrics(y, 0.95, 100, 100, 500.0 + 15.0 * (i % 4) as f64), &b);
        }
        let (e, _) = est.estimate(&metrics(1.0, 0.95, 100, 100, 500.0), &b);
        assert!(e < 2.5, "estimator must track the drift: {e}");
    }

    #[test]
    fn invalidation_returns_to_cold_start() {
        let mut est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let b = backend();
        for _ in 0..16 {
            est.observe(&metrics(5.0, 0.9, 100, 100, 500.0), &b);
        }
        assert!(est.gp_active());
        est.invalidate();
        assert!(!est.gp_active());
        assert_eq!(est.n_samples(), 0);
        // EMA path with fresh post-transition observations (the EMA stores
        // the mildly utilization-corrected rate: 9.0/0.9 = 10.0):
        est.observe(&metrics(9.0, 0.9, 100, 100, 500.0), &b);
        let (e, _) = est.estimate(&metrics(9.0, 0.9, 100, 100, 500.0), &b);
        assert!((e - 10.0).abs() < 1.0, "fresh estimate {e}");
    }

    #[test]
    fn disabled_filters_accept_everything() {
        let cfg = ObsConfig { signal_filter: false, model_filter: false, ..Default::default() };
        let mut est = CapacityEstimator::new(cfg, FeatureExtractor::LlmTokens);
        let b = backend();
        assert_eq!(est.observe(&metrics(5.0, 0.1, 0, 500, 500.0), &b), Verdict::Accepted);
        assert_eq!(est.stats.accepted, 1);
    }

    #[test]
    fn useful_time_matches_busy_arithmetic() {
        let mut ds2 = UsefulTimeEstimator::new();
        ds2.observe(&metrics(4.0, 0.5, 100, 100, 500.0));
        // records = 20 over busy 2.5s -> 8 rec/s claimed capacity
        assert!((ds2.estimate() - 8.0).abs() < 1e-6);
    }

    #[test]
    fn estimator_without_data_degrades_gracefully() {
        let est = CapacityEstimator::new(ObsConfig::default(), FeatureExtractor::LlmTokens);
        let (e, conf) = est.estimate(&metrics(0.0, 0.0, 0, 0, 500.0), &backend());
        assert!(e > 0.0);
        assert_eq!(conf, 0.0);
    }
}
