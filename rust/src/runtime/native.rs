//! Pure-Rust GP posterior + constrained acquisition — the numerical oracle
//! for the PJRT artifacts and the fallback backend when artifacts are
//! absent.  Mirrors `python/compile/model.py` exactly (same Matérn-5/2
//! kernel, same jitter, same EI × PoF combination) but in f64.

use super::{AcqPoint, GpHyper};
use crate::linalg::{cholesky, solve_lower, solve_lower_t, Mat};

const SQRT5: f64 = 2.23606797749979;
const JITTER: f64 = 1e-5;

fn matern52(a: &[f64], b: &[f64], lengthscale: f64, signal_var: f64) -> f64 {
    let d2: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .max(0.0);
    let r = d2.sqrt() / lengthscale.max(1e-12);
    let sr = SQRT5 * r;
    signal_var * (1.0 + sr + (5.0 / 3.0) * r * r) * (-sr).exp()
}

/// GP posterior (mean, variance incl. noise) at each query point.
pub fn gp_predict(
    xs: &[Vec<f64>],
    ys: &[f64],
    queries: &[Vec<f64>],
    h: GpHyper,
) -> Vec<(f64, f64)> {
    let n = xs.len();
    if n == 0 {
        return queries
            .iter()
            .map(|_| (h.mean, h.signal_var + h.noise_var))
            .collect();
    }
    let mut k = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(&xs[i], &xs[j], h.lengthscale, h.signal_var);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += h.noise_var + JITTER;
    }
    // Escalate jitter if needed (mirrors what a robust impl does; the AOT
    // graph relies on noise_var >= 1e-6 from fit_hyper instead).
    let l = {
        let mut boost = 0.0;
        loop {
            let mut kk = k.clone();
            if boost > 0.0 {
                for i in 0..n {
                    kk[(i, i)] += boost;
                }
            }
            if let Some(l) = cholesky(&kk) {
                break l;
            }
            boost = if boost == 0.0 { 1e-6 } else { boost * 10.0 };
            assert!(boost < 1.0, "GP covariance hopelessly ill-conditioned");
        }
    };
    let resid: Vec<f64> = ys.iter().map(|y| y - h.mean).collect();
    let alpha = solve_lower_t(&l, &solve_lower(&l, &resid));

    queries
        .iter()
        .map(|q| {
            let kq: Vec<f64> = xs
                .iter()
                .map(|x| matern52(q, x, h.lengthscale, h.signal_var))
                .collect();
            let mu = h.mean + kq.iter().zip(&alpha).map(|(a, b)| a * b).sum::<f64>();
            let v = solve_lower(&l, &kq);
            let var = (h.signal_var - v.iter().map(|x| x * x).sum::<f64>() + h.noise_var)
                .max(1e-9);
            (mu, var)
        })
        .collect()
}

pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Abramowitz–Stegun 7.1.26 rational approximation (|err| < 1.5e-7, matches
/// the f32 precision of the AOT path).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Expected improvement (maximization).
pub fn expected_improvement(mu: f64, sigma: f64, best: f64) -> f64 {
    let sigma = sigma.max(1e-9);
    let z = (mu - best) / sigma;
    (sigma * (z * norm_cdf(z) + norm_pdf(z))).max(0.0)
}

/// Constrained acquisition over candidate configurations.
#[allow(clippy::too_many_arguments)]
pub fn acquisition(
    thetas: &[Vec<f64>],
    uts: &[f64],
    mems: &[f64],
    cands: &[Vec<f64>],
    hyper_ut: GpHyper,
    hyper_mem: GpHyper,
    best_ut: f64,
    mem_limit: f64,
) -> Vec<AcqPoint> {
    let ut_post = gp_predict(thetas, uts, cands, hyper_ut);
    let mem_post = gp_predict(thetas, mems, cands, hyper_mem);
    ut_post
        .iter()
        .zip(&mem_post)
        .map(|(&(mu_u, var_u), &(mu_m, var_m))| {
            let sigma_u = var_u.sqrt();
            let sigma_m = var_m.sqrt().max(1e-9);
            let ei = expected_improvement(mu_u, sigma_u, best_ut);
            let pof = norm_cdf((mem_limit - mu_m) / sigma_m);
            AcqPoint { alpha: ei * pof, ei, pof, mu_ut: mu_u, mu_mem: mu_m, sigma_ut: sigma_u }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> GpHyper {
        GpHyper { lengthscale: 1.0, signal_var: 1.0, noise_var: 1e-4, mean: 0.0 }
    }

    #[test]
    fn interpolates_observations() {
        let xs = vec![vec![0.0], vec![1.0], vec![2.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let out = gp_predict(&xs, &ys, &xs, hyper());
        for (o, y) in out.iter().zip(&ys) {
            assert!((o.0 - y).abs() < 0.02, "{} vs {}", o.0, y);
            assert!(o.1 < 0.01);
        }
    }

    #[test]
    fn reverts_to_prior_far_away() {
        let xs = vec![vec![0.0], vec![0.5]];
        let ys = vec![5.0, 5.2];
        let h = GpHyper { mean: 1.0, ..hyper() };
        let out = gp_predict(&xs, &ys, &[vec![100.0]], h);
        assert!((out[0].0 - 1.0).abs() < 1e-3);
        assert!((out[0].1 - (1.0 + 1e-4)).abs() < 1e-3);
    }

    #[test]
    fn empty_training_gives_prior() {
        let out = gp_predict(&[], &[], &[vec![0.0]], hyper());
        assert_eq!(out[0].0, 0.0);
        assert!((out[0].1 - 1.0001).abs() < 1e-9);
    }

    #[test]
    fn erf_accuracy() {
        // reference values
        for (x, e) in [(0.0, 0.0), (0.5, 0.5204998778), (1.0, 0.8427007929), (2.0, 0.9953222650)] {
            assert!((erf(x) - e).abs() < 2e-7, "erf({x})");
            assert!((erf(-x) + e).abs() < 2e-7);
        }
    }

    #[test]
    fn ei_properties() {
        // Higher mean -> higher EI; zero sigma -> max(mu-best, 0).
        assert!(expected_improvement(2.0, 0.5, 1.0) > expected_improvement(1.5, 0.5, 1.0));
        assert!((expected_improvement(2.0, 1e-12, 1.0) - 1.0).abs() < 1e-6);
        assert!(expected_improvement(0.0, 1e-12, 1.0) < 1e-9);
    }

    #[test]
    fn acquisition_zeroes_infeasible() {
        let thetas = vec![vec![0.1], vec![0.9]];
        let uts = vec![1.0, 2.0];
        let mems = vec![9000.0, 9500.0]; // both far above limit
        let h_m = GpHyper { lengthscale: 1.0, signal_var: 100.0, noise_var: 1.0, mean: 9000.0 };
        let out = acquisition(&thetas, &uts, &mems, &[vec![0.5]], hyper(), h_m, 2.0, 1000.0);
        assert!(out[0].pof < 1e-6);
        assert!(out[0].alpha < 1e-6);
    }
}
