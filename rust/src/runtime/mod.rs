//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the scheduling path.
//!
//! Python never runs at request time — `make artifacts` lowers the Layer-2
//! JAX graphs (which embed the Layer-1 Pallas Matérn kernel) to HLO *text*,
//! and this module compiles them once per process on the PJRT CPU client.
//!
//! Fixed artifact shapes (see `artifacts/meta.json`):
//! * `gp_predict`:      x[64,6], y[64], mask[64], q[32,6], params[4] → (mu[32], var[32])
//! * `bo_acquisition`:  θ[64,6], ut[64], mem[64], mask[64], cand[128,6],
//!                      p_ut[4], p_mem[4], scalars[3] → (α, EI, PoF, μ_ut, μ_mem, σ_ut)[128]
//!
//! [`GpBackend`] abstracts over the PJRT path and the pure-Rust
//! [`native`] oracle (used in tests and via `TRIDENT_NATIVE_GP=1`).
//!
//! The PJRT path depends on the external `xla` and `anyhow` crates, which
//! the offline build environment does not ship; it is compiled only under
//! the off-by-default `pjrt` cargo feature (see `rust/Cargo.toml`).  The
//! default build always uses the native backend, with identical call-site
//! signatures so no caller changes across builds.

pub mod native;

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

/// Fallible runtime result.  Without the `pjrt` feature the native backend
/// cannot fail, but the `Result` signatures are kept so call sites are
/// identical whether or not the feature is enabled.
#[cfg(not(feature = "pjrt"))]
pub type Result<T> = std::result::Result<T, std::convert::Infallible>;

/// AOT shape constants — must match `python/compile/model.py`.
pub const N_TRAIN: usize = 64;
pub const M_QUERY: usize = 32;
pub const N_CAND: usize = 128;
pub const D_FEAT: usize = 6;

/// GP hyper-parameters: [lengthscale, signal_var, noise_var, mean].
#[derive(Debug, Clone, Copy)]
pub struct GpHyper {
    pub lengthscale: f64,
    pub signal_var: f64,
    pub noise_var: f64,
    pub mean: f64,
}

impl GpHyper {
    #[cfg(feature = "pjrt")]
    fn as_f32(&self) -> [f32; 4] {
        [
            self.lengthscale as f32,
            self.signal_var as f32,
            self.noise_var as f32,
            self.mean as f32,
        ]
    }
}

/// Heuristic hyper-parameter fit (the paper does not specify its fitting
/// procedure; see DESIGN.md): constant mean = sample mean, signal variance
/// = sample variance, noise = 5% of signal variance, lengthscale = median
/// pairwise distance of the (normalized) inputs.
pub fn fit_hyper(xs: &[Vec<f64>], ys: &[f64]) -> GpHyper {
    let n = ys.len();
    if n == 0 {
        return GpHyper { lengthscale: 0.5, signal_var: 1.0, noise_var: 0.05, mean: 0.0 };
    }
    let mean = ys.iter().sum::<f64>() / n as f64;
    let var = (ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / n as f64).max(1e-6);
    let mut dists = Vec::new();
    let cap = 24.min(n); // median over a bounded subset keeps this O(1)-ish
    for i in 0..cap {
        for j in (i + 1)..cap {
            let d2: f64 = xs[i]
                .iter()
                .zip(&xs[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let d = d2.sqrt();
            if d > 1e-9 {
                dists.push(d);
            }
        }
    }
    let lengthscale = if dists.is_empty() {
        0.5
    } else {
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        dists[dists.len() / 2].clamp(0.05, 10.0)
    };
    GpHyper { lengthscale, signal_var: var, noise_var: (0.05 * var).max(1e-6), mean }
}

/// Output of one acquisition evaluation for a candidate configuration.
#[derive(Debug, Clone, Copy)]
pub struct AcqPoint {
    pub alpha: f64,
    pub ei: f64,
    pub pof: f64,
    pub mu_ut: f64,
    pub mu_mem: f64,
    pub sigma_ut: f64,
}

/// Compiled PJRT executables for both artifacts.
#[cfg(feature = "pjrt")]
pub struct Artifacts {
    _client: xla::PjRtClient,
    gp: xla::PjRtLoadedExecutable,
    acq: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Artifacts {
    /// Compile `gp_predict.hlo.txt` + `bo_acquisition.hlo.txt` from `dir`.
    pub fn load(dir: &str) -> Result<Artifacts> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = format!("{dir}/{name}.hlo.txt");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse {path}"))?;
            client
                .compile(&xla::XlaComputation::from_proto(&proto))
                .with_context(|| format!("compile {name}"))
        };
        let gp = load("gp_predict")?;
        let acq = load("bo_acquisition")?;
        Ok(Artifacts { _client: client, gp, acq })
    }

    /// Default artifact directory: `$TRIDENT_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> String {
        std::env::var("TRIDENT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}

#[cfg(feature = "pjrt")]
fn lit1(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

#[cfg(feature = "pjrt")]
fn lit2(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// Pad `xs`/`ys` (most recent last) into fixed N_TRAIN × D_FEAT buffers.
/// If more than N_TRAIN points are given, the oldest are dropped.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn pad_train(xs: &[Vec<f64>], ys: &[f64]) -> (Vec<f32>, Vec<f32>, Vec<f32>, usize) {
    let n = xs.len().min(N_TRAIN);
    let off = xs.len() - n;
    let mut x = vec![0f32; N_TRAIN * D_FEAT];
    let mut y = vec![0f32; N_TRAIN];
    let mut m = vec![0f32; N_TRAIN];
    for i in 0..n {
        let src = &xs[off + i];
        for d in 0..D_FEAT.min(src.len()) {
            x[i * D_FEAT + d] = src[d] as f32;
        }
        y[i] = ys[off + i] as f32;
        m[i] = 1.0;
    }
    (x, y, m, n)
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn pad_queries(qs: &[Vec<f64>], rows: usize) -> Vec<f32> {
    let mut q = vec![0f32; rows * D_FEAT];
    for (i, src) in qs.iter().enumerate().take(rows) {
        for d in 0..D_FEAT.min(src.len()) {
            q[i * D_FEAT + d] = src[d] as f32;
        }
    }
    q
}

/// Backend for all GP math: PJRT artifacts (production) or native Rust
/// (oracle / fallback).
pub enum GpBackend {
    #[cfg(feature = "pjrt")]
    Pjrt(Artifacts),
    Native,
}

impl GpBackend {
    /// Construct from the environment: native if `TRIDENT_NATIVE_GP=1` or
    /// artifacts are missing, PJRT otherwise.
    #[cfg(feature = "pjrt")]
    pub fn from_env() -> GpBackend {
        if std::env::var("TRIDENT_NATIVE_GP").map(|v| v == "1").unwrap_or(false) {
            return GpBackend::Native;
        }
        match Artifacts::load(&Artifacts::default_dir()) {
            Ok(a) => GpBackend::Pjrt(a),
            Err(e) => {
                eprintln!(
                    "trident: PJRT artifacts unavailable ({e:#}); falling back to native GP \
                     (run `make artifacts`)"
                );
                GpBackend::Native
            }
        }
    }

    /// Without the `pjrt` feature the native oracle is the only backend.
    #[cfg(not(feature = "pjrt"))]
    pub fn from_env() -> GpBackend {
        GpBackend::Native
    }

    pub fn is_native(&self) -> bool {
        match self {
            GpBackend::Native => true,
            #[cfg(feature = "pjrt")]
            GpBackend::Pjrt(_) => false,
        }
    }

    /// GP posterior at `queries` given observations `(xs, ys)`.
    /// Returns (mean, variance) per query; variance includes observation
    /// noise (matching Eq. (2)/(3) usage).
    pub fn gp_predict(
        &self,
        xs: &[Vec<f64>],
        ys: &[f64],
        queries: &[Vec<f64>],
        hyper: GpHyper,
    ) -> Result<Vec<(f64, f64)>> {
        match self {
            GpBackend::Native => Ok(native::gp_predict(xs, ys, queries, hyper)),
            #[cfg(feature = "pjrt")]
            GpBackend::Pjrt(a) => {
                let (x, y, m, _) = pad_train(xs, ys);
                let mut out = Vec::with_capacity(queries.len());
                for chunk in queries.chunks(M_QUERY).map(<[Vec<f64>]>::to_vec) {
                    let q = pad_queries(&chunk, M_QUERY);
                    let args = [
                        lit2(&x, N_TRAIN, D_FEAT)?,
                        lit1(&y),
                        lit1(&m),
                        lit2(&q, M_QUERY, D_FEAT)?,
                        lit1(&hyper.as_f32().to_vec()),
                    ];
                    let mut res = a.gp.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
                    let tup = res.decompose_tuple()?;
                    let mu = tup[0].to_vec::<f32>()?;
                    let var = tup[1].to_vec::<f32>()?;
                    for i in 0..chunk.len() {
                        out.push((mu[i] as f64, var[i] as f64));
                    }
                }
                Ok(out)
            }
        }
    }

    /// Constrained-BO acquisition over `cands` (Eqs. 5–9).
    #[allow(clippy::too_many_arguments)]
    pub fn acquisition(
        &self,
        thetas: &[Vec<f64>],
        uts: &[f64],
        mems: &[f64],
        cands: &[Vec<f64>],
        hyper_ut: GpHyper,
        hyper_mem: GpHyper,
        best_ut: f64,
        mem_limit: f64,
    ) -> Result<Vec<AcqPoint>> {
        match self {
            GpBackend::Native => Ok(native::acquisition(
                thetas, uts, mems, cands, hyper_ut, hyper_mem, best_ut, mem_limit,
            )),
            #[cfg(feature = "pjrt")]
            GpBackend::Pjrt(a) => {
                let (x, ut, m, _) = pad_train(thetas, uts);
                let (_, mem, _, _) = pad_train(thetas, mems);
                let scalars = [best_ut as f32, mem_limit as f32, 0.0f32];
                let mut out = Vec::with_capacity(cands.len());
                for chunk in cands.chunks(N_CAND).map(<[Vec<f64>]>::to_vec) {
                    let c = pad_queries(&chunk, N_CAND);
                    let args = [
                        lit2(&x, N_TRAIN, D_FEAT)?,
                        lit1(&ut),
                        lit1(&mem),
                        lit1(&m),
                        lit2(&c, N_CAND, D_FEAT)?,
                        lit1(&hyper_ut.as_f32().to_vec()),
                        lit1(&hyper_mem.as_f32().to_vec()),
                        lit1(&scalars.to_vec()),
                    ];
                    let mut res = a.acq.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
                    let tup = res.decompose_tuple()?;
                    let get = |k: usize| -> Result<Vec<f32>> { Ok(tup[k].to_vec::<f32>()?) };
                    let (alpha, ei, pof) = (get(0)?, get(1)?, get(2)?);
                    let (mu_u, mu_m, sig_u) = (get(3)?, get(4)?, get(5)?);
                    for i in 0..chunk.len() {
                        out.push(AcqPoint {
                            alpha: alpha[i] as f64,
                            ei: ei[i] as f64,
                            pof: pof[i] as f64,
                            mu_ut: mu_u[i] as f64,
                            mu_mem: mu_m[i] as f64,
                            sigma_ut: sig_u[i] as f64,
                        });
                    }
                }
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_hyper_sane() {
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 10.0; 2]).collect();
        let ys: Vec<f64> = (0..10).map(|i| 5.0 + i as f64).collect();
        let h = fit_hyper(&xs, &ys);
        assert!((h.mean - 9.5).abs() < 1e-9);
        assert!(h.signal_var > 1.0);
        assert!(h.lengthscale > 0.0 && h.lengthscale <= 10.0);
        assert!(h.noise_var > 0.0);
    }

    #[test]
    fn fit_hyper_degenerate() {
        let h = fit_hyper(&[], &[]);
        assert!(h.signal_var > 0.0);
        let h1 = fit_hyper(&[vec![0.5]], &[3.0]);
        assert_eq!(h1.mean, 3.0);
    }

    #[test]
    fn pad_train_drops_oldest() {
        let xs: Vec<Vec<f64>> = (0..70).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..70).map(|i| i as f64).collect();
        let (x, y, m, n) = pad_train(&xs, &ys);
        assert_eq!(n, N_TRAIN);
        assert_eq!(m.iter().sum::<f32>(), N_TRAIN as f32);
        assert_eq!(y[0], 6.0); // oldest 6 dropped
        assert_eq!(x[0], 6.0);
        assert_eq!(y[N_TRAIN - 1], 69.0);
    }
}
