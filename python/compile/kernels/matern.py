"""Layer-1 Pallas kernel: masked Matérn-5/2 cross-covariance matrix.

This is the compute hot spot of Trident's observation/adaptation layers: every
GP posterior evaluation (capacity estimation, BO surrogates) needs the dense
cross-covariance between two point sets.  The kernel is written as a tiled
``pallas_call`` so the HBM<->VMEM schedule is explicit:

* the grid is ``(M/bm, N/bn)`` tiles of the output covariance matrix;
* each tile loads an ``(bm, D)`` block of ``a`` and a ``(bn, D)`` block of
  ``b`` into VMEM, computes the pairwise squared distances through a single
  ``(bm, D) x (D, bn)`` matmul (MXU-friendly) plus row/col norms (VPU), and
  applies the Matérn-5/2 shape function elementwise;
* row/column validity masks are multiplied in, so padded points contribute
  exactly zero covariance (the Layer-2 model restores a unit diagonal for
  padded training points, keeping the Cholesky well-posed).

``interpret=True`` is mandatory here: the artifacts are executed by the CPU
PJRT client from Rust, and a real TPU lowering would emit a Mosaic
custom-call that the CPU plugin cannot run (see DESIGN.md
§Hardware-Adaptation for the TPU mapping notes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes for the covariance grid.  Shapes used by the AOT artifacts are
# small (N=64, M<=128), so a 32x32 tile keeps the grid non-trivial (exercising
# the BlockSpec schedule) while each VMEM-resident tile stays tiny:
# 2*(32*D) + 32*32 floats ~ 5.5 KiB for D=6, far under the ~16 MiB VMEM
# budget of a real TPU core.
BLOCK_M = 32
BLOCK_N = 32

_SQRT5 = 2.23606797749979


def _matern_tile_kernel(a_ref, b_ref, ma_ref, mb_ref, p_ref, o_ref):
    """Compute one (bm, bn) tile of the masked Matérn-5/2 covariance.

    a_ref:  (bm, D) VMEM block of the left point set
    b_ref:  (bn, D) VMEM block of the right point set
    ma_ref: (bm, 1) row validity mask block
    mb_ref: (bn, 1) column validity mask block
    p_ref:  (2,)    [lengthscale, signal_variance] (broadcast to every tile)
    o_ref:  (bm, bn) output tile
    """
    a = a_ref[...]
    b = b_ref[...]
    ls = p_ref[0]
    sf2 = p_ref[1]

    # Pairwise squared distances via the MXU: |a|^2 + |b|^2 - 2 a.b^T.
    dots = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    an = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    bn = jnp.sum(b * b, axis=1, keepdims=True)  # (bn, 1)
    d2 = jnp.maximum(an + bn.T - 2.0 * dots, 0.0)

    # Matérn 5/2 shape function on scaled distance r/ls.
    r = jnp.sqrt(d2) / jnp.maximum(ls, 1e-12)
    sr = _SQRT5 * r
    k = sf2 * (1.0 + sr + (5.0 / 3.0) * r * r) * jnp.exp(-sr)

    # Validity masks: padded rows/cols contribute zero covariance.
    o_ref[...] = k * (ma_ref[...] * mb_ref[...].T)


def _pad_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matern52_cross(a, b, mask_a, mask_b, params, *, interpret=True):
    """Masked Matérn-5/2 cross-covariance ``K[i, j] = m_a[i] m_b[j] k(a_i, b_j)``.

    Arguments
    ---------
    a:       (M, D) float32 left points
    b:       (N, D) float32 right points
    mask_a:  (M,)  float32 validity of rows (1.0 valid / 0.0 padded)
    mask_b:  (N,)  float32 validity of cols
    params:  (2,)  float32 [lengthscale, signal_variance]

    Returns (M, N) float32.  Shapes are padded up to BLOCK multiples
    internally; the result is sliced back.
    """
    m, d = a.shape
    n, _ = b.shape
    mp = ((m + BLOCK_M - 1) // BLOCK_M) * BLOCK_M
    np_ = ((n + BLOCK_N - 1) // BLOCK_N) * BLOCK_N

    a_p = _pad_to(a.astype(jnp.float32), mp, 0)
    b_p = _pad_to(b.astype(jnp.float32), np_, 0)
    ma_p = _pad_to(mask_a.astype(jnp.float32).reshape(m, 1), mp, 0)
    mb_p = _pad_to(mask_b.astype(jnp.float32).reshape(n, 1), np_, 0)

    grid = (mp // BLOCK_M, np_ // BLOCK_N)
    out = pl.pallas_call(
        _matern_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_M, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p, ma_p, mb_p, params.astype(jnp.float32))
    return out[:m, :n]
