"""Pure-jnp correctness oracles for the Pallas kernel and the Layer-2 models.

Everything here is deliberately naive and unpadded: the pytest suite checks
that the tiled/masked production code in ``matern.py`` / ``model.py`` agrees
with these within float32 tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

_SQRT5 = 2.23606797749979


def matern52(a, b, lengthscale, signal_var):
    """Naive (M, N) Matérn-5/2 cross-covariance, no masking, no tiling."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    d2 = jnp.maximum(
        jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :] - 2.0 * a @ b.T,
        0.0,
    )
    r = jnp.sqrt(d2) / jnp.maximum(lengthscale, 1e-12)
    sr = _SQRT5 * r
    return signal_var * (1.0 + sr + (5.0 / 3.0) * r * r) * jnp.exp(-sr)


def gp_predict_ref(x_train, y_train, x_query, lengthscale, signal_var, noise_var, mean):
    """Textbook GP posterior (unpadded, dense) used as the model.py oracle.

    Returns (posterior mean, predictive variance incl. observation noise).
    """
    n = x_train.shape[0]
    k_tt = matern52(x_train, x_train, lengthscale, signal_var)
    # Same jitter as compile.model._JITTER so ill-conditioned cases agree.
    k_tt = k_tt + (noise_var + 1e-5) * jnp.eye(n, dtype=jnp.float32)
    l = jnp.linalg.cholesky(k_tt)
    resid = (y_train - mean).astype(jnp.float32)
    alpha = jnp.linalg.solve(k_tt, resid)
    k_qt = matern52(x_query, x_train, lengthscale, signal_var)
    mu = mean + k_qt @ alpha
    v = jnp.linalg.solve(l, k_qt.T)  # lower-triangular solve (dense solve is fine as oracle)
    var = signal_var - jnp.sum(v * v, axis=0) + noise_var
    return mu, jnp.maximum(var, 1e-9)


def norm_cdf(z):
    import jax

    return 0.5 * (1.0 + jax.lax.erf(jnp.asarray(z, jnp.float32) / jnp.sqrt(2.0)))


def norm_pdf(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def expected_improvement(mu, sigma, best, xi=0.0):
    """Closed-form EI for maximization."""
    sigma = jnp.maximum(sigma, 1e-9)
    z = (mu - best - xi) / sigma
    return sigma * (z * norm_cdf(z) + norm_pdf(z))


def prob_feasible(mu_mem, sigma_mem, limit):
    """P(mem <= limit) under the memory surrogate."""
    sigma_mem = jnp.maximum(sigma_mem, 1e-9)
    return norm_cdf((limit - mu_mem) / sigma_mem)
