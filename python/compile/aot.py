"""AOT-lower the Layer-2 models to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
Writes:
  artifacts/gp_predict.hlo.txt
  artifacts/bo_acquisition.hlo.txt
  artifacts/meta.json            (shapes + operand order, read by Rust)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

try:
    from compile import model
except ImportError:
    from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so Rust can
    unwrap a fixed-arity tuple regardless of output count)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args) -> str:
    """Lower for the *tpu* platform: CPU-platform lowering rewrites
    ``cholesky``/``triangular_solve`` into LAPACK typed-FFI custom-calls that
    xla_extension 0.5.1 cannot execute, while the TPU path keeps them as pure
    HLO ops which the CPU PJRT compiler expands internally
    (CholeskyExpander / TriangularSolveExpander).  Verified numerics in
    rust/tests/runtime_roundtrip.rs."""
    traced = jax.jit(fn).trace(*example_args)
    lowered = traced.lower(lowering_platforms=("tpu",))
    return to_hlo_text(lowered)


def lower_gp_predict() -> str:
    return lower_fn(model.gp_predict, model.gp_predict_example_args())


def lower_bo_acquisition() -> str:
    return lower_fn(model.bo_acquisition, model.bo_acquisition_example_args())


META = {
    "n_train": model.N_TRAIN,
    "m_query": model.M_QUERY,
    "n_cand": model.N_CAND,
    "d_feat": model.D_FEAT,
    "gp_predict": {
        "inputs": ["x_train[N,D]", "y_train[N]", "mask[N]", "x_query[M,D]", "params[4]"],
        "outputs": ["mu[M]", "var[M]"],
    },
    "bo_acquisition": {
        "inputs": [
            "theta_obs[N,D]", "ut_obs[N]", "mem_obs[N]", "mask[N]",
            "cand[C,D]", "params_ut[4]", "params_mem[4]", "scalars[3]",
        ],
        "outputs": ["alpha[C]", "ei[C]", "pof[C]", "mu_ut[C]", "mu_mem[C]", "sigma_ut[C]"],
    },
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, fn in (
        ("gp_predict", lower_gp_predict),
        ("bo_acquisition", lower_bo_acquisition),
    ):
        text = fn()
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(META, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
