"""Layer-2 JAX models for Trident's observation and adaptation layers.

Two build-time-compiled compute graphs, both calling the Layer-1 Pallas
Matérn kernel (``kernels/matern.py``):

* ``gp_predict`` — masked GP posterior over workload descriptors.  This is
  the observation layer's capacity estimator: Rust pads the filtered
  observation buffer into fixed-shape operands and gets back the posterior
  mean (capacity estimate) and predictive variance (used by the stage-2
  anomaly filter and by cold-start logic).
* ``bo_acquisition`` — the adaptation layer's memory-constrained BO step:
  two GP surrogates (sustainable throughput UT, peak device memory Mem)
  evaluated over a candidate configuration batch, combined into the
  constrained acquisition  alpha(theta) = EI_UT(theta) * PoF(theta)  of
  Eq. (8) in the paper.

Masking algebra (padding correctness): with validity mask ``m`` the Pallas
kernel returns ``K = (m m^T) o k(X, X)``; adding ``diag(1 - m)`` gives a
matrix that is exactly block-diagonal between the valid block and an
identity on the padded block, and padded residuals are zeroed, so
``alpha = K'^{-1} (m o (y - mu0))`` has zeros in all padded slots and
cross-covariances ``k_*`` are likewise masked — padded points contribute
*exactly* nothing to posterior mean or variance.  Verified against the
unpadded oracle in ``python/tests/test_gp.py``.

Everything is float32 and fixed-shape so the graphs AOT-compile once
(``aot.py``) and run from Rust via PJRT with zero Python at runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # package-style import (pytest from python/)
    from compile.kernels.matern import matern52_cross
except ImportError:  # script-style import (python -m compile.aot from python/)
    from .kernels.matern import matern52_cross

# Fixed AOT shapes (mirrored in artifacts/meta.json and rust/src/runtime/).
N_TRAIN = 64   # max retained observations per operator GP
M_QUERY = 32   # workload-descriptor queries per call (batched per round)
N_CAND = 128   # BO candidate configurations scored per call
D_FEAT = 6     # padded feature/config dimensionality

_JITTER = 1e-5


def _masked_posterior(x_train, y_train, mask, x_query, params):
    """Shared masked-GP posterior.  params = [ls, sf2, sn2, mean0]."""
    n = x_train.shape[0]
    ls, sf2, sn2, mean0 = params[0], params[1], params[2], params[3]
    kparams = jnp.stack([ls, sf2])

    ones_q = jnp.ones((x_query.shape[0],), jnp.float32)
    k_tt = matern52_cross(x_train, x_train, mask, mask, kparams)
    # Unit diagonal on padded slots keeps the Cholesky well-posed; valid
    # slots get the noise + jitter diagonal.
    diag = (1.0 - mask) + mask * (sn2 + _JITTER)
    k_tt = k_tt + jnp.diag(diag)

    chol = jnp.linalg.cholesky(k_tt)
    resid = mask * (y_train - mean0)
    alpha = jax.scipy.linalg.cho_solve((chol, True), resid)

    k_qt = matern52_cross(x_query, x_train, ones_q, mask, kparams)
    mu = mean0 + k_qt @ alpha

    v = jax.scipy.linalg.solve_triangular(chol, k_qt.T, lower=True)
    var = sf2 - jnp.sum(v * v, axis=0) + sn2
    return mu, jnp.maximum(var, 1e-9)


def gp_predict(x_train, y_train, mask, x_query, params):
    """Observation-layer capacity GP.

    x_train: (N_TRAIN, D_FEAT)  padded workload descriptors
    y_train: (N_TRAIN,)         padded observed throughputs (0 where padded)
    mask:    (N_TRAIN,)         1.0 valid / 0.0 padded
    x_query: (M_QUERY, D_FEAT)  query descriptors
    params:  (4,)               [lengthscale, signal_var, noise_var, mean]

    Returns (mu[M_QUERY], var[M_QUERY]) — predictive distribution of the
    *observed* throughput (variance includes the noise term), matching
    Eq. (2)/(3) usage in the paper.
    """
    return _masked_posterior(x_train, y_train, mask, x_query, params)


def _erf_approx(x):
    """Abramowitz–Stegun 7.1.26 rational erf (|err| < 1.5e-7 ≈ f32 eps).

    xla_extension 0.5.1's HLO text parser predates the `erf` opcode, so the
    AOT graph must stick to elementwise mul/add/exp.  Mirrored exactly in
    rust/src/runtime/native.rs so both backends agree bit-for-bit-ish.
    """
    s = jnp.sign(x)
    x = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t + 0.254829592
    return s * (1.0 - poly * t * jnp.exp(-x * x))


def _norm_cdf(z):
    return 0.5 * (1.0 + _erf_approx(z / jnp.sqrt(jnp.float32(2.0))))


def _norm_pdf(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.float32(jnp.pi))


def bo_acquisition(theta_obs, ut_obs, mem_obs, mask, cand, params_ut, params_mem, scalars):
    """Adaptation-layer constrained acquisition (Eqs. 5-8).

    theta_obs: (N_TRAIN, D_FEAT) evaluated configurations (padded)
    ut_obs:    (N_TRAIN,)        observed sustainable throughput
    mem_obs:   (N_TRAIN,)        observed peak device memory
    mask:      (N_TRAIN,)        validity
    cand:      (N_CAND, D_FEAT)  candidate configurations to score
    params_ut, params_mem: (4,)  GP hyperparameters per surrogate
    scalars:   (3,)              [best_feasible_ut, mem_limit(=cap-delta), xi]

    Returns (alpha, ei, pof, mu_ut, mu_mem, sigma_ut) each (N_CAND,).
    """
    best, limit, xi = scalars[0], scalars[1], scalars[2]

    mu_u, var_u = _masked_posterior(theta_obs, ut_obs, mask, cand, params_ut)
    mu_m, var_m = _masked_posterior(theta_obs, mem_obs, mask, cand, params_mem)

    sigma_u = jnp.sqrt(var_u)
    z = (mu_u - best - xi) / sigma_u
    ei = sigma_u * (z * _norm_cdf(z) + _norm_pdf(z))

    sigma_m = jnp.sqrt(var_m)
    pof = _norm_cdf((limit - mu_m) / sigma_m)

    alpha = ei * pof
    return alpha, ei, pof, mu_u, mu_m, sigma_u


def gp_predict_example_args():
    z = jnp.zeros
    return (
        z((N_TRAIN, D_FEAT), jnp.float32),
        z((N_TRAIN,), jnp.float32),
        z((N_TRAIN,), jnp.float32),
        z((M_QUERY, D_FEAT), jnp.float32),
        z((4,), jnp.float32),
    )


def bo_acquisition_example_args():
    z = jnp.zeros
    return (
        z((N_TRAIN, D_FEAT), jnp.float32),
        z((N_TRAIN,), jnp.float32),
        z((N_TRAIN,), jnp.float32),
        z((N_TRAIN,), jnp.float32),
        z((N_CAND, D_FEAT), jnp.float32),
        z((4,), jnp.float32),
        z((4,), jnp.float32),
        z((3,), jnp.float32),
    )
