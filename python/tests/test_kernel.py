"""Layer-1 correctness: Pallas Matérn-5/2 kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, lengthscales, and data ranges; every case asserts
allclose against ``kernels/ref.py``.  This is the CORE correctness signal for
the compiled artifacts (the same pallas_call lowers into both AOT graphs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matern import matern52_cross, BLOCK_M, BLOCK_N
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _params(ls, sf2):
    return jnp.asarray([ls, sf2], jnp.float32)


def _rand(rng, m, d, scale=1.0):
    return jnp.asarray(rng.normal(size=(m, d)) * scale, jnp.float32)


@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    d=st.integers(1, 6),
    ls=st.floats(0.05, 10.0),
    sf2=st.floats(0.01, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference(m, n, d, ls, sf2, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, d)
    b = _rand(rng, n, d)
    k = matern52_cross(a, b, jnp.ones((m,)), jnp.ones((n,)), _params(ls, sf2))
    kr = ref.matern52(a, b, ls, sf2)
    np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-5, atol=2e-5)


@given(
    m=st.integers(2, 50),
    d=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_symmetry_and_diagonal(m, d, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, d)
    ones = jnp.ones((m,))
    k = np.asarray(matern52_cross(a, a, ones, ones, _params(0.8, 2.5)))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)
    # k(x, x) = signal variance on the diagonal
    np.testing.assert_allclose(np.diag(k), 2.5, rtol=1e-5)
    # PSD-ish: covariance values never exceed the signal variance
    assert k.max() <= 2.5 * (1 + 1e-5)


@given(
    m=st.integers(3, 40),
    n=st.integers(3, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_zeroes_padded_rows_cols(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, 4)
    b = _rand(rng, n, 4)
    ma = jnp.asarray((rng.random(m) > 0.4).astype(np.float32))
    mb = jnp.asarray((rng.random(n) > 0.4).astype(np.float32))
    k = np.asarray(matern52_cross(a, b, ma, mb, _params(1.0, 1.0)))
    kr = np.asarray(ref.matern52(a, b, 1.0, 1.0))
    expect = kr * np.outer(np.asarray(ma), np.asarray(mb))
    np.testing.assert_allclose(k, expect, rtol=2e-5, atol=2e-5)


def test_tile_boundaries_exact_multiples():
    # Shapes exactly at and around the BlockSpec tile boundaries.
    rng = np.random.default_rng(7)
    for m in (BLOCK_M - 1, BLOCK_M, BLOCK_M + 1, 2 * BLOCK_M):
        for n in (BLOCK_N - 1, BLOCK_N, BLOCK_N + 1, 2 * BLOCK_N):
            a = _rand(rng, m, 3)
            b = _rand(rng, n, 3)
            k = matern52_cross(a, b, jnp.ones((m,)), jnp.ones((n,)), _params(0.5, 1.0))
            kr = ref.matern52(a, b, 0.5, 1.0)
            np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-5, atol=2e-5)


def test_identical_points_give_signal_variance():
    a = jnp.zeros((5, 6), jnp.float32)
    k = np.asarray(matern52_cross(a, a, jnp.ones((5,)), jnp.ones((5,)), _params(1.0, 3.0)))
    np.testing.assert_allclose(k, 3.0, rtol=1e-6)


def test_distance_monotonicity():
    # Covariance decays monotonically with distance.
    a = jnp.zeros((1, 1), jnp.float32)
    b = jnp.asarray(np.linspace(0, 5, 50)[:, None], jnp.float32)
    k = np.asarray(matern52_cross(a, b, jnp.ones((1,)), jnp.ones((50,)), _params(1.0, 1.0)))[0]
    assert np.all(np.diff(k) <= 1e-7)


def test_float32_inputs_accepted_from_other_dtypes():
    rng = np.random.default_rng(3)
    a64 = jnp.asarray(rng.normal(size=(9, 2)))  # float64->float32 path
    b32 = jnp.asarray(rng.normal(size=(11, 2)), jnp.float32)
    k = matern52_cross(a64, b32, jnp.ones((9,)), jnp.ones((11,)), _params(1.0, 1.0))
    assert k.dtype == jnp.float32
    kr = ref.matern52(a64.astype(jnp.float32), b32, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-5, atol=2e-5)
