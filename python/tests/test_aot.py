"""AOT path: lowering must produce custom-call-free HLO text that preserves
the jax-eval semantics (numerics checked again from Rust in
rust/tests/runtime_roundtrip.rs)."""

import numpy as np

from compile import aot, model


def test_gp_predict_hlo_is_pure():
    text = aot.lower_gp_predict()
    assert "ENTRY" in text
    assert "custom-call" not in text, "typed-FFI custom-calls break xla_extension 0.5.1"
    assert "cholesky" in text
    assert "f32[64,6]" in text  # operand layout the Rust runtime pads to


def test_bo_acquisition_hlo_is_pure():
    text = aot.lower_bo_acquisition()
    assert "ENTRY" in text
    assert "custom-call" not in text
    assert "f32[128,6]" in text


def test_meta_matches_model_constants():
    assert aot.META["n_train"] == model.N_TRAIN == 64
    assert aot.META["m_query"] == model.M_QUERY == 32
    assert aot.META["n_cand"] == model.N_CAND == 128
    assert aot.META["d_feat"] == model.D_FEAT == 6
    assert len(aot.META["gp_predict"]["inputs"]) == 5
    assert len(aot.META["bo_acquisition"]["inputs"]) == 8


def test_lowered_graph_semantics_match_eager():
    # The traced/lowered function and the eager function agree on a real case.
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    xt = np.zeros((model.N_TRAIN, model.D_FEAT), np.float32)
    xt[:8, :2] = rng.normal(size=(8, 2))
    y = np.zeros((model.N_TRAIN,), np.float32)
    y[:8] = rng.normal(size=8)
    mask = np.zeros((model.N_TRAIN,), np.float32)
    mask[:8] = 1.0
    xq = np.zeros((model.M_QUERY, model.D_FEAT), np.float32)
    xq[:, :2] = rng.normal(size=(model.M_QUERY, 2))
    params = np.asarray([1.0, 1.0, 0.01, 0.0], np.float32)
    args = tuple(jnp.asarray(a) for a in (xt, y, mask, xq, params))

    mu_e, var_e = model.gp_predict(*args)
    mu_c, var_c = jax.jit(model.gp_predict)(*args)
    np.testing.assert_allclose(np.asarray(mu_e), np.asarray(mu_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_e), np.asarray(var_c), rtol=1e-5, atol=1e-5)
