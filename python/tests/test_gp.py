"""Layer-2 correctness: masked GP posterior vs the unpadded textbook oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

N, M, D = model.N_TRAIN, model.M_QUERY, model.D_FEAT


def _pad_case(rng, n_valid, d_valid, ls, sf2, sn2, mean):
    """Build padded fixed-shape operands + the unpadded reference inputs."""
    xt_v = rng.normal(size=(n_valid, d_valid)).astype(np.float32)
    y_v = (mean + np.sin(xt_v.sum(axis=1)) + 0.1 * rng.normal(size=n_valid)).astype(np.float32)
    xq_v = rng.normal(size=(M, d_valid)).astype(np.float32)

    xt = np.zeros((N, D), np.float32)
    xt[:n_valid, :d_valid] = xt_v
    # Padded rows get arbitrary garbage coordinates — they must not matter.
    xt[n_valid:] = rng.normal(size=(N - n_valid, D)) * 100.0
    y = np.zeros((N,), np.float32)
    y[:n_valid] = y_v
    y[n_valid:] = rng.normal(size=N - n_valid) * 1e3
    mask = np.zeros((N,), np.float32)
    mask[:n_valid] = 1.0
    xq = np.zeros((M, D), np.float32)
    xq[:, :d_valid] = xq_v
    params = np.asarray([ls, sf2, sn2, mean], np.float32)
    return (jnp.asarray(xt), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(xq),
            jnp.asarray(params)), (xt_v, y_v, xq_v)


@given(
    n_valid=st.integers(2, N),
    d_valid=st.integers(1, D),
    ls=st.floats(0.3, 3.0),
    sf2=st.floats(0.1, 10.0),
    sn2=st.floats(1e-4, 0.5),
    mean=st.floats(-5.0, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_padding_invariance_vs_reference(n_valid, d_valid, ls, sf2, sn2, mean, seed):
    rng = np.random.default_rng(seed)
    padded, (xt_v, y_v, xq_v) = _pad_case(rng, n_valid, d_valid, ls, sf2, sn2, mean)
    mu, var = model.gp_predict(*padded)
    mu_r, var_r = ref.gp_predict_ref(
        jnp.asarray(xt_v), jnp.asarray(y_v), jnp.asarray(xq_v), ls, sf2, sn2, mean
    )
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_r), rtol=5e-3, atol=5e-3)


def test_interpolates_training_points_at_low_noise():
    rng = np.random.default_rng(0)
    n_valid = 10
    padded, (xt_v, y_v, _) = _pad_case(rng, n_valid, 3, 1.0, 2.0, 1e-4, 0.0)
    xt, y, mask, _, params = padded
    xq = np.zeros((M, D), np.float32)
    xq[:n_valid, :3] = xt_v
    mu, var = model.gp_predict(xt, y, mask, jnp.asarray(xq), params)
    np.testing.assert_allclose(np.asarray(mu)[:n_valid], y_v, atol=0.03)


def test_empty_mask_returns_prior():
    z = jnp.zeros
    params = jnp.asarray([1.0, 2.0, 0.1, 7.0], jnp.float32)
    mu, var = model.gp_predict(
        z((N, D), jnp.float32), z((N,), jnp.float32), z((N,), jnp.float32),
        z((M, D), jnp.float32), params)
    np.testing.assert_allclose(np.asarray(mu), 7.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var), 2.1, rtol=1e-4)


def test_variance_shrinks_near_data_grows_far():
    rng = np.random.default_rng(1)
    padded, _ = _pad_case(rng, 20, 2, 1.0, 1.0, 0.01, 0.0)
    xt, y, mask, _, params = padded
    xq = np.zeros((M, D), np.float32)
    xq[0, :2] = np.asarray(xt)[0, :2]          # on a training point
    xq[1, :2] = np.asarray([50.0, -50.0])      # far away
    mu, var = model.gp_predict(xt, y, mask, jnp.asarray(xq), params)
    var = np.asarray(var)
    assert var[0] < 0.1
    assert var[1] > 0.9  # reverts to prior sf2 + sn2
