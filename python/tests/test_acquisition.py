"""Adaptation-layer acquisition (EI x PoF) correctness and invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

N, C, D = model.N_TRAIN, model.N_CAND, model.D_FEAT


def _case(rng, n_valid, best=None, limit=8000.0):
    th = np.zeros((N, D), np.float32)
    th[:n_valid] = rng.random((n_valid, D))
    ut = np.zeros((N,), np.float32)
    ut[:n_valid] = 10.0 + 5.0 * rng.random(n_valid)
    mem = np.zeros((N,), np.float32)
    mem[:n_valid] = 4000.0 + 3000.0 * rng.random(n_valid)
    mask = np.zeros((N,), np.float32)
    mask[:n_valid] = 1.0
    cand = rng.random((C, D)).astype(np.float32)
    if best is None:
        best = float(ut[:n_valid].max())
    pu = np.asarray([0.5, 4.0, 0.05, float(ut[:n_valid].mean())], np.float32)
    pm = np.asarray([0.5, 1.5e6, 1e4, float(mem[:n_valid].mean())], np.float32)
    sc = np.asarray([best, limit, 0.0], np.float32)
    args = tuple(jnp.asarray(a) for a in (th, ut, mem, mask, cand, pu, pm, sc))
    return args


@given(n_valid=st.integers(3, N), seed=st.integers(0, 2**31 - 1))
def test_outputs_well_formed(n_valid, seed):
    rng = np.random.default_rng(seed)
    alpha, ei, pof, mu_u, mu_m, sig_u = (np.asarray(o) for o in model.bo_acquisition(*_case(rng, n_valid)))
    assert np.all(ei >= -1e-6), "EI must be non-negative"
    assert np.all((pof >= -1e-6) & (pof <= 1 + 1e-6)), "PoF is a probability"
    np.testing.assert_allclose(alpha, ei * pof, rtol=1e-4, atol=1e-6)
    assert np.all(sig_u > 0)


def test_ei_matches_closed_form():
    rng = np.random.default_rng(2)
    args = _case(rng, 12)
    alpha, ei, pof, mu_u, mu_m, sig_u = model.bo_acquisition(*args)
    best = float(np.asarray(args[7])[0])
    ei_ref = ref.expected_improvement(mu_u, sig_u, best)
    np.testing.assert_allclose(np.asarray(ei), np.asarray(ei_ref), rtol=1e-4, atol=1e-5)


def test_pof_monotone_in_limit():
    rng = np.random.default_rng(3)
    pofs = []
    for limit in (2000.0, 6000.0, 12000.0):
        args = _case(rng, 10, limit=limit)
        rng = np.random.default_rng(3)  # identical data each time
        _, _, pof, _, _, _ = model.bo_acquisition(*args)
        pofs.append(np.asarray(pof).mean())
    assert pofs[0] <= pofs[1] <= pofs[2]


def test_tight_limit_kills_acquisition():
    rng = np.random.default_rng(4)
    args = _case(rng, 15, limit=1.0)  # far below every observed memory
    alpha, _, pof, _, _, _ = model.bo_acquisition(*args)
    assert np.asarray(pof).max() < 0.05
    assert np.asarray(alpha).max() < np.asarray(_case(rng, 15, limit=1e7)[0]).size  # trivially finite
