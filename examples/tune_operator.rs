//! Memory-constrained BO demo: tune the TextOCR operator's inference-engine
//! configuration for the annual-report regime, comparing constrained vs
//! unconstrained exploration (paper Table 5's protocol on one operator).
//!
//!     cargo run --release --example tune_operator

use trident::adaptation::{ConfigTuner, Strategy, TunerConfig};
use trident::rngx::Rng;
use trident::runtime::GpBackend;
use trident::sim::{service, ItemAttrs};
use trident::workload::pdf;

fn main() {
    let pl = pdf::pipeline();
    let op = pl.operators.iter().find(|o| o.name == "text_ocr").unwrap();
    // annual-report blocks: heavy prefill
    let attrs = ItemAttrs { tokens_in: 633.0, tokens_out: 140.0, pixels_m: 0.25, frames: 1.0 };
    let cap = 65_536.0;
    let backend = GpBackend::from_env();
    let mut rng = Rng::new(1);

    for strategy in [Strategy::ConstrainedBo, Strategy::UnconstrainedBo] {
        let mut tuner = ConfigTuner::new(
            op.config_space.clone(),
            TunerConfig {
                strategy,
                budget: 30,
                n_init: 5,
                eta: 0.6,
                mem_limit_mb: cap - 2048.0,
                seed: 3,
            },
        );
        let mut ooms = 0;
        while !tuner.done() {
            let theta = tuner.next_candidate(&backend);
            let ut = service::true_unit_rate(&op.service, &theta, &attrs) * rng.lognormal(0.0, 0.05);
            let mem = service::expected_mem(&op.service, &theta, &attrs) * rng.lognormal(0.0, 0.06);
            let oom = mem > cap;
            ooms += oom as u32;
            tuner.record(theta, ut, mem, oom);
        }
        let default_ut = service::true_unit_rate(&op.service, &op.config_space.default_config(), &attrs);
        match tuner.best() {
            Some(best) => println!(
                "{strategy:?}: best {:.2} rec/s ({:.2}x default), mem {:.1} GB, {} OOMs during search\n  theta = {:?}",
                best.ut,
                best.ut / default_ut,
                best.mem_mb / 1024.0,
                ooms,
                best.theta
            ),
            None => println!("{strategy:?}: no feasible configuration found ({ooms} OOMs)"),
        }
    }
}
