//! Quickstart: a 2-node cluster running the PDF curation pipeline for ten
//! simulated minutes under the full Trident closed loop, printing the
//! windowed throughput curve.
//!
//!     make artifacts && cargo run --release --example quickstart

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Variant};
use trident::sim::ItemAttrs;
use trident::workload::pdf;

fn main() {
    let cluster = ClusterSpec::homogeneous(2, 128.0, 512.0, 4, 65536.0, 2500.0);
    let cfg = TridentConfig::default();
    let src = ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 };
    let mut coord = Coordinator::new(
        pdf::pipeline(),
        cluster,
        Box::new(pdf::trace(10_000)),
        cfg,
        Variant::trident(),
        src,
        0,
    );
    let report = coord.run(600.0);
    println!("pipeline:   {}", report.pipeline);
    println!("policy:     {}", report.variant);
    println!("throughput: {:.2} documents/s", report.throughput);
    println!("processed:  {} documents", report.items_processed);
    println!("OOM events: {} ({:.0}s downtime)", report.oom_events, report.oom_downtime_s);
    println!("MILP solves: {}", report.milp_ms.len());
    print!("curve:      ");
    for (_, thr) in report.series.iter().step_by(6) {
        print!("{:.1} ", thr);
    }
    println!();
}
