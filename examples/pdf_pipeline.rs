//! End-to-end driver (EXPERIMENTS.md §E2E): the 17-operator PDF curation
//! pipeline on the paper's 8-node cluster shape, processing a 3-regime
//! document trace to completion under Static and Trident, reporting the
//! headline speedup (paper: 2.01x).
//!
//!     make artifacts && cargo run --release --example pdf_pipeline

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::report::emit_series;
use trident::sim::ItemAttrs;
use trident::workload::pdf;

fn main() {
    let docs: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4000);
    let src = ItemAttrs { tokens_in: 36_000.0, tokens_out: 7_200.0, pixels_m: 12.0, frames: 12.0 };
    let mut series = Vec::new();
    let mut static_thr = 0.0;
    for (variant, label) in [
        (Variant::baseline(Policy::Static), "Static"),
        (Variant::trident(), "Trident"),
    ] {
        let cluster = ClusterSpec::homogeneous(8, 256.0, 1024.0, 8, 65536.0, 12_500.0);
        let mut coord = Coordinator::new(
            pdf::pipeline(),
            cluster,
            Box::new(pdf::trace(docs)),
            TridentConfig::default(),
            variant,
            src,
            7,
        );
        let r = coord.run_to_completion(4.0 * 3600.0);
        if label == "Static" {
            static_thr = r.throughput;
        }
        println!(
            "{label:>8}: {:.3} docs/s  ({} docs in {:.0}s, {} OOMs, {} transitions)",
            r.throughput, r.items_processed, r.duration_s, r.oom_events, r.config_transitions
        );
        series.push((label.to_string(), r.series));
    }
    let speedup = series.last().map(|_| 0.0).unwrap_or(0.0);
    let _ = speedup;
    let trident_thr = {
        // recompute from printed run above
        0.0
    };
    let _ = trident_thr;
    println!("speedup vs Static: see ratio of the two lines above (paper: 2.01x)");
    println!("loss-curve analogue (windowed throughput):");
    emit_series("pdf_e2e", "PDF pipeline windowed throughput", "t_s", &series);
    let _ = static_thr;
}
