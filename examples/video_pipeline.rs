//! The 9-operator video curation pipeline (short-form -> long-form regime
//! shift) under Static and Trident on the 8-node cluster (paper: 1.88x).
//!
//!     make artifacts && cargo run --release --example video_pipeline

use trident::config::{ClusterSpec, TridentConfig};
use trident::coordinator::{Coordinator, Policy, Variant};
use trident::report::emit_series;
use trident::sim::ItemAttrs;
use trident::workload::video;

fn main() {
    let vids: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6000);
    let src = ItemAttrs { tokens_in: 5_400.0, tokens_out: 480.0, pixels_m: 0.9, frames: 600.0 };
    let mut series = Vec::new();
    for (variant, label) in [
        (Variant::baseline(Policy::Static), "Static"),
        (Variant::trident(), "Trident"),
    ] {
        let cluster = ClusterSpec::homogeneous(8, 256.0, 1024.0, 8, 65536.0, 12_500.0);
        let mut coord = Coordinator::new(
            video::pipeline(),
            cluster,
            Box::new(video::trace(vids)),
            TridentConfig::default(),
            variant,
            src,
            11,
        );
        let r = coord.run_to_completion(4.0 * 3600.0);
        println!(
            "{label:>8}: {:.3} videos/s  ({} clips out, {:.0}s, {} OOMs, {} transitions)",
            r.throughput, r.items_processed, r.duration_s, r.oom_events, r.config_transitions
        );
        series.push((label.to_string(), r.series));
    }
    emit_series("video_e2e", "Video pipeline windowed throughput", "t_s", &series);
}
